//! Cost-model format autotuner.
//!
//! At engine build time, for each (weight shape, sparsity level) tuple the
//! autotuner scores every registered `(format, kernel)` matmul candidate —
//! either with a deterministic cost model or by microbenchmarking the real
//! kernels — picks the winner, and caches the decision in a schema-versioned
//! on-disk cache keyed by shape + sparsity + n:m:g config. A tuned layer then
//! routes through [`crate::dispatch`] with an exact phase-1 signature hit, so
//! steady-state execution pays zero per-call tuning overhead.
//!
//! Cache invalidation is by construction: the key embeds every input the
//! decision depends on (op, M/K/N, sparsity permille, n:m:g parameters, and
//! the active compute [`Backend`] — the SIMD kernels shift the
//! dense-vs-irregular trade-off), so a shape, sparsity, or backend change
//! misses the cache and re-tunes, and a schema bump drops the whole file. Serialization goes through
//! [`Json::to_string_sorted`], so "same decisions" implies "byte-identical
//! cache file" — the determinism contract the autotune tests pin down.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::dispatch::Dispatcher;
use crate::kernels::backend::{self, Backend};
use crate::formats::{
    AnyTensor, BcsrTensor, CooTensor, CscTensor, CsrTensor, EllTensor, Layout, MaskedTensor,
    NmgTensor,
};
use crate::ops::OpKind;
use crate::runtime::{Json, Manifest};
use crate::tensor::DenseTensor;
use crate::util::rng::Pcg64;

/// Cache schema version: bump on any change to the key format, the decision
/// fields, or the cost model's units. A loaded cache with a different schema
/// is dropped wholesale (stale decisions are worse than a re-tune).
/// v2: keys embed the compute backend; cost model is vector-width-aware.
pub const TUNE_SCHEMA_VERSION: u64 = 2;

/// Block edge used for BCSR candidates.
const BCSR_BLOCK: usize = 4;

/// How candidates are scored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TunePolicy {
    /// Deterministic analytic cost model (effective-flop units). Same inputs
    /// always produce the same decisions — the reproducible default.
    CostModel,
    /// Wall-clock microbenchmark of the real kernels through the dispatcher
    /// (best-of-`iters` after `warmup` unrecorded runs). More faithful,
    /// machine-dependent; the cache makes replays deterministic.
    Microbench {
        /// Unrecorded warm-up runs per candidate.
        warmup: usize,
        /// Recorded runs per candidate (best is kept).
        iters: usize,
    },
}

impl TunePolicy {
    /// Stable name recorded in cached decisions.
    pub fn name(&self) -> &'static str {
        match self {
            TunePolicy::CostModel => "cost_model",
            TunePolicy::Microbench { .. } => "microbench",
        }
    }
}

/// Sparsity statistics of a weight matrix, measured once per tuning query.
#[derive(Debug, Clone, Copy)]
pub struct WeightStats {
    /// Matrix rows (M of the matmul).
    pub rows: usize,
    /// Matrix cols (K of the matmul).
    pub cols: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Maximum nonzeros in any single row (ELL width).
    pub max_row_nnz: usize,
    /// Occupied 4x4 blocks (BCSR payload count; 0 when not block-divisible).
    pub blocks_occupied: usize,
}

impl WeightStats {
    /// Measure a dense weight.
    pub fn measure(d: &DenseTensor) -> WeightStats {
        assert_eq!(d.rank(), 2, "autotuner expects 2-D weights");
        let (rows, cols) = (d.rows(), d.cols());
        let mut nnz = 0usize;
        let mut max_row_nnz = 0usize;
        for r in 0..rows {
            let row_nnz = (0..cols).filter(|&c| d.get2(r, c) != 0.0).count();
            nnz += row_nnz;
            max_row_nnz = max_row_nnz.max(row_nnz);
        }
        let mut blocks_occupied = 0usize;
        if rows % BCSR_BLOCK == 0 && cols % BCSR_BLOCK == 0 {
            for br in 0..rows / BCSR_BLOCK {
                for bc in 0..cols / BCSR_BLOCK {
                    let occupied = (0..BCSR_BLOCK).any(|i| {
                        (0..BCSR_BLOCK)
                            .any(|j| d.get2(br * BCSR_BLOCK + i, bc * BCSR_BLOCK + j) != 0.0)
                    });
                    if occupied {
                        blocks_occupied += 1;
                    }
                }
            }
        }
        WeightStats { rows, cols, nnz, max_row_nnz, blocks_occupied }
    }

    /// Fraction of zero entries in parts-per-thousand (integer, so it can be
    /// embedded in cache keys without float formatting hazards).
    pub fn sparsity_permille(&self) -> usize {
        let numel = self.rows * self.cols;
        if numel == 0 {
            return 0;
        }
        1000 - (self.nnz * 1000) / numel
    }
}

/// Analytic cost of running `weight @ B` (B is `cols x ncols` dense) with the
/// weight stored in `layout`, in effective-flop units: useful flops divided
/// by each kernel's measured-on-this-codebase efficiency relative to the
/// blocked dense GEMM. `None` means the layout is not a viable candidate for
/// this weight (e.g. BCSR on non-divisible shapes, n:m:g without a config).
///
/// The cost is backend-aware: under the SIMD backend the dense, n:m:g, and
/// BCSR kernels have vector twins while the scalar-indexed formats (CSR,
/// ELL) do not, so the irregular formats' relative cost scales with the
/// backend's vector width (they forfeit the vector speedup the others get).
pub fn model_cost(
    layout: Layout,
    stats: &WeightStats,
    ncols: usize,
    nmg: Option<(usize, usize, usize)>,
    be: Backend,
) -> Option<f64> {
    let n2 = 2.0 * ncols as f64;
    // Relative penalty for formats the vector backend cannot accelerate:
    // 1.0 on the scalar backend, vector_width / 4 under SIMD (the gather-
    // bound kernels recover roughly half the 8-lane speedup in practice).
    let irregular = (be.vector_width() as f64 / 4.0).max(1.0);
    // Per-format inefficiency factors (relative to dense-GEMM flops): the
    // structured formats stream contiguously (near-dense), scalar CSR pays
    // per-element indexing — the paper's §1 blocked-vs-flexible trade-off.
    match layout {
        Layout::Dense => Some(n2 * (stats.rows * stats.cols) as f64 * 1.0),
        Layout::Nmg => {
            let (n, m, _) = nmg?;
            // After n:m pruning, n/m of the elements survive; the kernel
            // streams them slab-contiguously.
            let kept = (stats.rows * stats.cols) as f64 * n as f64 / m as f64;
            Some(n2 * kept * 1.25)
        }
        Layout::Bcsr => {
            if stats.rows % BCSR_BLOCK != 0 || stats.cols % BCSR_BLOCK != 0 {
                return None;
            }
            // Every stored block multiplies densely, zeros included.
            let slots = (stats.blocks_occupied * BCSR_BLOCK * BCSR_BLOCK) as f64;
            Some(n2 * slots * 1.1)
        }
        Layout::Ell => Some(n2 * (stats.rows * stats.max_row_nnz) as f64 * 2.5 * irregular),
        Layout::Csr => Some(n2 * stats.nnz as f64 * 3.0 * irregular),
        // Csc/Coo/Masked/Nm matmuls exist but are never cheaper than the
        // candidates above under this model; leaving them out keeps the
        // candidate set (and the cache) small.
        _ => None,
    }
}

/// One cached tuning decision.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// Winning storage layout.
    pub layout: Layout,
    /// Human-readable kernel the dispatcher will route to.
    pub kernel: String,
    /// Winning score (effective flops for `CostModel`, best seconds for
    /// `Microbench`).
    pub cost: f64,
    /// Policy that produced the decision.
    pub policy: String,
}

/// Kernel label for a layout's registered matmul implementation.
fn kernel_name(layout: Layout) -> &'static str {
    match layout {
        Layout::Dense => "dense_gemm::matmul",
        Layout::Csr => "csr_gemm::spmm",
        Layout::Csc => "csc_gemm::spmm",
        Layout::Ell => "ell_gemm::spmm",
        Layout::Bcsr => "bcsr_gemm::spmm",
        Layout::Nmg => "nmg_gemm::spmm",
        _ => "dispatch::fallback",
    }
}

fn parse_layout(s: &str) -> Result<Layout> {
    Ok(match s {
        "Dense" => Layout::Dense,
        "Csr" => Layout::Csr,
        "Csc" => Layout::Csc,
        "Coo" => Layout::Coo,
        "Ell" => Layout::Ell,
        "Bcsr" => Layout::Bcsr,
        "Nm" => Layout::Nm,
        "Nmg" => Layout::Nmg,
        "Masked" => Layout::Masked,
        other => bail!("unknown layout {other:?} in autotune cache"),
    })
}

/// Schema-versioned decision cache with deterministic serialization.
#[derive(Debug, Default)]
pub struct TuneCache {
    entries: BTreeMap<String, Decision>,
}

impl TuneCache {
    /// Empty cache.
    pub fn new() -> TuneCache {
        TuneCache::default()
    }

    /// Cache path: `$STEN_AUTOTUNE_CACHE` or `target/autotune_cache.json`.
    /// Deliberately *not* under `artifacts/` — the artifact runtime treats an
    /// artifacts directory without a manifest as an error.
    pub fn default_path() -> PathBuf {
        match std::env::var_os("STEN_AUTOTUNE_CACHE") {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from("target/autotune_cache.json"),
        }
    }

    /// Load from disk. A missing file is an empty cache; a schema mismatch
    /// drops every entry (decisions from another schema are untrusted).
    pub fn load(path: &Path) -> Result<TuneCache> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(TuneCache::new());
            }
            Err(e) => return Err(e).with_context(|| format!("reading {path:?}")),
        };
        let root = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let schema = root.get("schema").ok_or_else(|| anyhow!("cache missing schema"))?.usize()?;
        if schema as u64 != TUNE_SCHEMA_VERSION {
            return Ok(TuneCache::new());
        }
        let mut entries = BTreeMap::new();
        if let Some(Json::Obj(map)) = root.get("entries") {
            for (key, v) in map {
                let dec = Decision {
                    layout: parse_layout(
                        v.get("layout").ok_or_else(|| anyhow!("entry missing layout"))?.str()?,
                    )?,
                    kernel: v
                        .get("kernel")
                        .ok_or_else(|| anyhow!("entry missing kernel"))?
                        .str()?
                        .to_string(),
                    cost: v.get("cost").ok_or_else(|| anyhow!("entry missing cost"))?.f64()?,
                    policy: v
                        .get("policy")
                        .ok_or_else(|| anyhow!("entry missing policy"))?
                        .str()?
                        .to_string(),
                };
                entries.insert(key.clone(), dec);
            }
        }
        Ok(TuneCache { entries })
    }

    /// Serialize (sorted keys, stable bytes) and write to disk.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
            }
        }
        std::fs::write(path, self.to_json_text()).with_context(|| format!("writing {path:?}"))
    }

    /// The exact bytes [`Self::save`] writes.
    pub fn to_json_text(&self) -> String {
        let mut entries = HashMap::new();
        for (key, d) in &self.entries {
            let mut obj = HashMap::new();
            obj.insert("layout".to_string(), Json::Str(d.layout.to_string()));
            obj.insert("kernel".to_string(), Json::Str(d.kernel.clone()));
            obj.insert("cost".to_string(), Json::Num(d.cost));
            obj.insert("policy".to_string(), Json::Str(d.policy.clone()));
            entries.insert(key.clone(), Json::Obj(obj));
        }
        let mut root = HashMap::new();
        root.insert("schema".to_string(), Json::Num(TUNE_SCHEMA_VERSION as f64));
        root.insert("entries".to_string(), Json::Obj(entries));
        Json::Obj(root).to_string_sorted()
    }

    /// Cached decision for `key`.
    pub fn get(&self, key: &str) -> Option<&Decision> {
        self.entries.get(key)
    }

    /// Insert a decision.
    pub fn insert(&mut self, key: String, d: Decision) {
        self.entries.insert(key, d);
    }

    /// Number of cached decisions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no decisions are cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Cache key: embeds every input the decision depends on, so invalidation on
/// shape / sparsity / config / backend change falls out of key inequality
/// (a decision tuned under SIMD must not be replayed on a scalar-only host).
pub fn tune_key(
    stats: &WeightStats,
    ncols: usize,
    nmg: Option<(usize, usize, usize)>,
    be: Backend,
) -> String {
    let nmg_part = match nmg {
        Some((n, m, g)) => format!("{n}:{m}:{g}"),
        None => "none".to_string(),
    };
    format!(
        "matmul:m{}k{}n{}:sp{}:nmg{}:be{}",
        stats.rows,
        stats.cols,
        ncols,
        stats.sparsity_permille(),
        nmg_part,
        be.name()
    )
}

/// Store a dense weight in `layout`. Every conversion except `Nmg` is
/// lossless; `Nmg` re-runs the grouped-n:m sparsifier, which is also lossless
/// when the weight was already pruned to that pattern (the engine's case).
pub fn materialize(
    d: &DenseTensor,
    layout: Layout,
    nmg: Option<(usize, usize, usize)>,
) -> Result<AnyTensor> {
    Ok(match layout {
        Layout::Dense => AnyTensor::Dense(d.clone()),
        Layout::Csr => AnyTensor::Csr(CsrTensor::from_dense(d)),
        Layout::Csc => AnyTensor::Csc(CscTensor::from_dense(d)),
        Layout::Coo => AnyTensor::Coo(CooTensor::from_dense(d)),
        Layout::Ell => AnyTensor::Ell(EllTensor::from_dense(d)),
        Layout::Masked => AnyTensor::Masked(MaskedTensor::from_dense(d)),
        Layout::Bcsr => AnyTensor::Bcsr(BcsrTensor::from_dense(d, BCSR_BLOCK, BCSR_BLOCK)),
        Layout::Nmg => {
            let (n, m, g) = nmg.ok_or_else(|| anyhow!("n:m:g candidate without a config"))?;
            AnyTensor::Nmg(NmgTensor::from_dense(d, n, m, g))
        }
        other => bail!("cannot materialize autotune layout {other}"),
    })
}

/// A [`Decision`] as a manifest/cache JSON object
/// (layout / kernel / cost / policy).
pub fn decision_to_json(d: &Decision) -> Json {
    let mut obj = HashMap::new();
    obj.insert("layout".to_string(), Json::Str(d.layout.to_string()));
    obj.insert("kernel".to_string(), Json::Str(d.kernel.clone()));
    obj.insert("cost".to_string(), Json::Num(d.cost));
    obj.insert("policy".to_string(), Json::Str(d.policy.clone()));
    Json::Obj(obj)
}

/// Parse a [`Decision`] back out of its manifest JSON object.
pub fn decision_from_json(j: &Json) -> Result<Decision> {
    let field = |k: &str| j.get(k).ok_or_else(|| anyhow!("autotune decision missing {k:?}"));
    Ok(Decision {
        layout: parse_layout(field("layout")?.str()?)?,
        kernel: field("kernel")?.str()?.to_string(),
        cost: field("cost")?.f64()?,
        policy: field("policy")?.str()?.to_string(),
    })
}

/// Materialize a tuned weight *and* record its decision in the artifact
/// manifest under the tune cache key: the deployed artifact pins the exact
/// layout the autotuner chose, and [`Autotuner::from_manifest`] replays it
/// without re-tuning.
pub fn materialize_into_manifest(
    manifest: &mut Manifest,
    key: &str,
    d: &DenseTensor,
    dec: &Decision,
    nmg: Option<(usize, usize, usize)>,
) -> Result<AnyTensor> {
    manifest.set_autotune(key, decision_to_json(dec));
    materialize(d, dec.layout, nmg)
}

/// The autotuner: policy + cache + hit counters.
pub struct Autotuner {
    /// Scoring policy.
    pub policy: TunePolicy,
    /// Decision cache (load/save via [`TuneCache`]).
    pub cache: TuneCache,
    /// Queries answered from the cache.
    pub hits: u64,
    /// Queries that ran the scoring loop.
    pub misses: u64,
}

impl Autotuner {
    /// Autotuner with an empty cache.
    pub fn new(policy: TunePolicy) -> Autotuner {
        Autotuner::with_cache(policy, TuneCache::new())
    }

    /// Autotuner over a pre-loaded cache.
    pub fn with_cache(policy: TunePolicy, cache: TuneCache) -> Autotuner {
        Autotuner { policy, cache, hits: 0, misses: 0 }
    }

    /// Replay tuner over a manifest's embedded autotune decisions
    /// ([`crate::runtime::Manifest::autotune`]): the cache is pre-seeded,
    /// so every [`Autotuner::choose`] with matching inputs is a pure cache
    /// hit — a deployed artifact reproduces its tuned layouts exactly.
    pub fn from_manifest(policy: TunePolicy, manifest: &Manifest) -> Result<Autotuner> {
        let mut cache = TuneCache::new();
        for (key, dec) in manifest.autotune() {
            cache.insert(key.clone(), decision_from_json(dec)?);
        }
        Ok(Autotuner::with_cache(policy, cache))
    }

    /// Enumerate candidate layouts for `weight @ dense` from the
    /// dispatcher's registered matmul signatures, filtered to layouts this
    /// weight can actually be stored in. Sorted for determinism.
    pub fn candidates(
        &self,
        d: &Dispatcher,
        stats: &WeightStats,
        nmg: Option<(usize, usize, usize)>,
    ) -> Vec<Layout> {
        let mut out: Vec<Layout> = d
            .registered_inputs(OpKind::MatMul)
            .into_iter()
            .filter(|sig| sig.len() == 2 && sig[1] == Layout::Dense)
            .map(|sig| sig[0])
            .filter(|&l| model_cost(l, stats, 1, nmg, backend::active()).is_some())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Pick the best layout for `weight @ B` where B is `weight.cols x ncols`
    /// dense. Answers from the cache when the key matches; otherwise scores
    /// every candidate under the policy, caches, and returns the winner.
    pub fn choose(
        &mut self,
        d: &Dispatcher,
        weight: &DenseTensor,
        ncols: usize,
        nmg: Option<(usize, usize, usize)>,
    ) -> Result<Decision> {
        let stats = WeightStats::measure(weight);
        let be = backend::active();
        let key = tune_key(&stats, ncols, nmg, be);
        if let Some(dec) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(dec.clone());
        }
        self.misses += 1;
        let cands = self.candidates(d, &stats, nmg);
        if cands.is_empty() {
            bail!("no matmul candidates registered for autotuning");
        }
        let mut best: Option<(Layout, f64)> = None;
        for &layout in &cands {
            let cost = match self.policy {
                TunePolicy::CostModel => {
                    model_cost(layout, &stats, ncols, nmg, be).expect("candidate was pre-filtered")
                }
                TunePolicy::Microbench { warmup, iters } => {
                    microbench(d, weight, layout, ncols, nmg, warmup, iters)?
                }
            };
            // Ties break toward the earlier (sorted) layout: deterministic.
            let better = match best {
                None => true,
                Some((_, c)) => cost < c,
            };
            if better {
                best = Some((layout, cost));
            }
        }
        let (layout, cost) = best.expect("non-empty candidate list");
        let dec = Decision {
            layout,
            kernel: kernel_name(layout).to_string(),
            cost,
            policy: self.policy.name().to_string(),
        };
        self.cache.insert(key, dec.clone());
        Ok(dec)
    }
}

/// Time `weight-as-layout @ B` through the dispatcher (exact phase-1 hit for
/// every candidate, since candidates come from registered signatures).
/// Returns best-of-`iters` seconds.
fn microbench(
    d: &Dispatcher,
    weight: &DenseTensor,
    layout: Layout,
    ncols: usize,
    nmg: Option<(usize, usize, usize)>,
    warmup: usize,
    iters: usize,
) -> Result<f64> {
    let wt = materialize(weight, layout, nmg)?;
    let mut rng = Pcg64::seeded(0x7u64);
    let b = AnyTensor::Dense(DenseTensor::randn(&[weight.cols(), ncols], &mut rng));
    for _ in 0..warmup {
        d.call_ref(OpKind::MatMul, &[&wt, &b])?;
    }
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        d.call_ref(OpKind::MatMul, &[&wt, &b])?;
        best = best.min(t.elapsed().as_secs_f64());
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Dispatcher;

    fn nmg_pruned_weight(rows: usize, cols: usize, seed: u64) -> DenseTensor {
        let mut rng = Pcg64::seeded(seed);
        let d = DenseTensor::randn(&[rows, cols], &mut rng);
        NmgTensor::from_dense(&d, 2, 4, 2).to_dense()
    }

    #[test]
    fn stats_measure_counts() {
        let mut d = DenseTensor::zeros(&[4, 8]);
        d.data_mut()[0] = 1.0; // row 0: 1 nnz, block (0,0)
        d.data_mut()[9] = 2.0; // row 1: 1 nnz, block (0,0)
        d.data_mut()[3 * 8 + 7] = 3.0; // row 3: 1 nnz, block (0,1)
        let s = WeightStats::measure(&d);
        assert_eq!((s.rows, s.cols, s.nnz, s.max_row_nnz), (4, 8, 3, 1));
        assert_eq!(s.blocks_occupied, 2);
        assert_eq!(s.sparsity_permille(), 1000 - 3000 / 32);
    }

    #[test]
    fn cost_model_prefers_structured_formats_on_structured_sparsity() {
        let w = nmg_pruned_weight(16, 32, 40);
        let s = WeightStats::measure(&w);
        let nmg = Some((2, 4, 2));
        let dense = model_cost(Layout::Dense, &s, 8, nmg, Backend::Scalar).unwrap();
        let nmg_c = model_cost(Layout::Nmg, &s, 8, nmg, Backend::Scalar).unwrap();
        let csr = model_cost(Layout::Csr, &s, 8, nmg, Backend::Scalar).unwrap();
        assert!(nmg_c < dense, "50% structured sparsity must beat dense");
        assert!(nmg_c < csr, "contiguous n:m:g must beat scalar CSR");
        // Without an n:m:g config the format is not a candidate at all.
        assert!(model_cost(Layout::Nmg, &s, 8, None, Backend::Scalar).is_none());
        // BCSR requires block-divisible shapes.
        let ragged = WeightStats { rows: 5, ..s };
        assert!(model_cost(Layout::Bcsr, &ragged, 8, nmg, Backend::Scalar).is_none());
    }

    #[test]
    fn cost_model_is_vector_width_aware() {
        let w = nmg_pruned_weight(16, 32, 47);
        let s = WeightStats::measure(&w);
        // Vectorizable formats cost the same under both backends (relative
        // units); the scalar-indexed formats get proportionally worse under
        // SIMD because they forfeit the vector speedup.
        for layout in [Layout::Dense, Layout::Nmg, Layout::Bcsr] {
            let sc = model_cost(layout, &s, 8, Some((2, 4, 2)), Backend::Scalar);
            let vc = model_cost(layout, &s, 8, Some((2, 4, 2)), Backend::Simd);
            assert_eq!(sc, vc, "{layout}: vector-twin formats keep their relative cost");
        }
        for layout in [Layout::Csr, Layout::Ell] {
            let sc = model_cost(layout, &s, 8, None, Backend::Scalar).unwrap();
            let vc = model_cost(layout, &s, 8, None, Backend::Simd).unwrap();
            let factor = (Backend::Simd.vector_width() as f64 / 4.0).max(1.0);
            assert_eq!(vc, sc * factor, "{layout}: irregular penalty scales with width");
        }
    }

    #[test]
    fn choose_picks_nmg_for_pruned_weight_and_caches() {
        let d = Dispatcher::with_builtins();
        let w = nmg_pruned_weight(16, 32, 41);
        let mut tuner = Autotuner::new(TunePolicy::CostModel);
        let dec = tuner.choose(&d, &w, 8, Some((2, 4, 2))).unwrap();
        assert_eq!(dec.layout, Layout::Nmg);
        assert_eq!(dec.kernel, "nmg_gemm::spmm");
        assert_eq!((tuner.hits, tuner.misses), (0, 1));
        // Second query with identical inputs hits the cache.
        let dec2 = tuner.choose(&d, &w, 8, Some((2, 4, 2))).unwrap();
        assert_eq!(dec, dec2);
        assert_eq!((tuner.hits, tuner.misses), (1, 1));
        // A different ncols is a different key: cache miss, fresh decision.
        tuner.choose(&d, &w, 16, Some((2, 4, 2))).unwrap();
        assert_eq!(tuner.misses, 2);
        assert_eq!(tuner.cache.len(), 2);
    }

    #[test]
    fn dense_weight_stays_dense() {
        let mut rng = Pcg64::seeded(42);
        let w = DenseTensor::randn(&[16, 32], &mut rng);
        let d = Dispatcher::with_builtins();
        let mut tuner = Autotuner::new(TunePolicy::CostModel);
        let dec = tuner.choose(&d, &w, 8, None).unwrap();
        assert_eq!(dec.layout, Layout::Dense, "fully dense weight: no sparse format can win");
    }

    #[test]
    fn cache_roundtrips_and_drops_on_schema_mismatch() {
        let mut cache = TuneCache::new();
        cache.insert(
            "matmul:m16k32n8:sp500:nmg2:4:2".to_string(),
            Decision {
                layout: Layout::Nmg,
                kernel: "nmg_gemm::spmm".to_string(),
                cost: 4096.0,
                policy: "cost_model".to_string(),
            },
        );
        let text = cache.to_json_text();
        let dir = std::env::temp_dir().join("sten_tune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        cache.save(&path).unwrap();
        let loaded = TuneCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        let key = "matmul:m16k32n8:sp500:nmg2:4:2";
        assert_eq!(loaded.get(key), cache.get(key));
        assert_eq!(loaded.to_json_text(), text, "save/load/save must be byte-stable");
        // Schema bump drops everything.
        let bumped = text.replace("\"schema\":2", "\"schema\":999");
        std::fs::write(&path, bumped).unwrap();
        assert!(TuneCache::load(&path).unwrap().is_empty());
        // Missing file is an empty cache, not an error.
        assert!(TuneCache::load(&dir.join("nope.json")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_embeds_and_replays_autotune_decisions() {
        let d = Dispatcher::with_builtins();
        let w = nmg_pruned_weight(16, 32, 46);
        let mut tuner = Autotuner::new(TunePolicy::CostModel);
        let dec = tuner.choose(&d, &w, 8, Some((2, 4, 2))).unwrap();
        // The key must reflect the backend `choose` resolved (the ambient
        // one — this test binary never forces backends).
        let key = tune_key(&WeightStats::measure(&w), 8, Some((2, 4, 2)), backend::active());

        // Materialize-and-record, then round-trip the manifest's autotune
        // section through serialized JSON.
        let mut manifest = Manifest::default();
        let wt =
            materialize_into_manifest(&mut manifest, &key, &w, &dec, Some((2, 4, 2))).unwrap();
        assert_eq!(wt.layout(), dec.layout, "materializes the recorded layout");
        let section = manifest.autotune_json().to_string_sorted();
        let doc = format!(r#"{{"artifacts": [], "autotune": {section}}}"#);
        let parsed = Manifest::parse(&doc).unwrap();
        assert_eq!(parsed.autotune(), manifest.autotune());
        assert_eq!(decision_from_json(&parsed.autotune()[&key]).unwrap(), dec);

        // Replay: identical decision, answered purely from the cache.
        let mut replay = Autotuner::from_manifest(TunePolicy::CostModel, &parsed).unwrap();
        let dec2 = replay.choose(&d, &w, 8, Some((2, 4, 2))).unwrap();
        assert_eq!(dec2, dec);
        assert_eq!((replay.hits, replay.misses), (1, 0), "replay must never re-tune");

        // A malformed embedded decision is a loud error, not a silent miss.
        let mut bad = Manifest::default();
        bad.set_autotune("k", Json::Str("not an object".to_string()));
        assert!(Autotuner::from_manifest(TunePolicy::CostModel, &bad).is_err());
    }

    #[test]
    fn microbench_policy_produces_a_valid_decision() {
        let d = Dispatcher::with_builtins();
        let w = nmg_pruned_weight(16, 32, 43);
        let mut tuner = Autotuner::new(TunePolicy::Microbench { warmup: 1, iters: 2 });
        let dec = tuner.choose(&d, &w, 4, Some((2, 4, 2))).unwrap();
        assert!(dec.cost > 0.0 && dec.cost.is_finite());
        assert_eq!(dec.policy, "microbench");
        let cands = tuner.candidates(&d, &WeightStats::measure(&w), Some((2, 4, 2)));
        assert!(cands.contains(&dec.layout));
    }

    #[test]
    fn materialized_candidates_dispatch_with_exact_hits() {
        let d = Dispatcher::with_builtins();
        let w = nmg_pruned_weight(16, 32, 44);
        let stats = WeightStats::measure(&w);
        let tuner = Autotuner::new(TunePolicy::CostModel);
        let mut rng = Pcg64::seeded(45);
        let b = AnyTensor::Dense(DenseTensor::randn(&[32, 6], &mut rng));
        let want = crate::kernels::dense_gemm::matmul_naive(&w, b.as_dense().unwrap());
        for layout in tuner.candidates(&d, &stats, Some((2, 4, 2))) {
            let wt = materialize(&w, layout, Some((2, 4, 2))).unwrap();
            d.stats.reset();
            let got = d.call_ref(OpKind::MatMul, &[&wt, &b]).unwrap();
            assert_eq!(d.stats.counts(), (1, 0, 0), "{layout}: tuned layers must hit phase 1");
            assert!(got.to_dense().allclose(&want, 1e-4, 1e-4), "{layout} kernel mismatch");
        }
    }
}
