//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` file is a `harness = false` binary that uses
//! [`Bench`] to run warmup + timed iterations and report median / mean / p95,
//! printing rows in the same shape as the paper's tables and figures.

use std::time::{Duration, Instant};

/// Result of a timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Median iteration time, seconds.
    pub median: f64,
    /// Mean iteration time, seconds.
    pub mean: f64,
    /// 95th-percentile iteration time, seconds.
    pub p95: f64,
    /// Minimum iteration time, seconds.
    pub min: f64,
    /// Number of measured iterations.
    pub iters: usize,
}

impl Sample {
    /// GFLOP/s given a per-iteration flop count.
    pub fn gflops(&self, flops: f64) -> f64 {
        flops / self.median / 1e9
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Upper bound on total measured wall time; measurement stops early
    /// (but after at least 3 iterations) once exceeded.
    pub max_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10, max_time: Duration::from_secs(20) }
    }
}

impl Bench {
    /// Quick preset for cheap microbenchmarks.
    pub fn quick() -> Self {
        Bench { warmup: 3, iters: 30, max_time: Duration::from_secs(5) }
    }

    /// Construct with explicit warmup/iters.
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters, ..Default::default() }
    }

    /// Run `f` under this configuration and collect a [`Sample`].
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Sample {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        let budget = Instant::now();
        for i in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
            if budget.elapsed() > self.max_time && i >= 2 {
                break;
            }
        }
        summarize(&times)
    }
}

/// Summarize raw per-iteration timings into a [`Sample`].
pub fn summarize(times: &[f64]) -> Sample {
    assert!(!times.is_empty());
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let n = sorted.len();
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let p95 = sorted[((n as f64 * 0.95) as usize).min(n - 1)];
    Sample { median, mean, p95, min: sorted[0], iters: n }
}

/// Print a bench table header: `name` followed by columns.
pub fn table_header(name: &str, cols: &[&str]) {
    println!("\n## {name}");
    println!("{}", cols.join("\t"));
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// A JSON scalar for [`JsonReport`] rows.
#[derive(Debug, Clone)]
pub enum JsonValue {
    /// Number field; non-finite values serialize as `null`.
    Num(f64),
    /// String field.
    Str(String),
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        JsonValue::Num(v)
    }
}

impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        JsonValue::Num(v as f64)
    }
}

impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        JsonValue::Str(v.to_string())
    }
}

/// Machine-readable bench output: a flat list of measurement points written
/// to `BENCH_<name>.json` so the perf trajectory is diffable across PRs
/// (each bench overwrites its own file on every run).
#[derive(Debug)]
pub struct JsonReport {
    name: String,
    rows: Vec<Vec<(String, JsonValue)>>,
}

impl JsonReport {
    /// New empty report for bench `name`.
    pub fn new(name: &str) -> Self {
        JsonReport { name: name.to_string(), rows: Vec::new() }
    }

    /// Append one measurement point (a flat key -> scalar object).
    pub fn row(&mut self, fields: &[(&str, JsonValue)]) {
        self.rows.push(fields.iter().map(|(k, v)| ((*k).to_string(), v.clone())).collect());
    }

    /// Number of points recorded so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no points have been recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize to a JSON array of flat objects.
    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, row) in self.rows.iter().enumerate() {
            s.push_str("  {");
            for (j, (k, v)) in row.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push('"');
                s.push_str(&json_escape(k));
                s.push_str("\": ");
                match v {
                    JsonValue::Num(x) if x.is_finite() => s.push_str(&format!("{x}")),
                    JsonValue::Num(_) => s.push_str("null"),
                    JsonValue::Str(t) => {
                        s.push('"');
                        s.push_str(&json_escape(t));
                        s.push('"');
                    }
                }
            }
            s.push('}');
            if i + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("]\n");
        s
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn write_in(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Write `BENCH_<name>.json` into the current directory (the cargo
    /// package root when run via `cargo bench`).
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        self.write_in(std::path::Path::new("."))
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse `--quick` / `--full` style bench flags from argv.
pub fn parse_mode() -> BenchMode {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--full") {
        BenchMode::Full
    } else if std::env::var("STEN_BENCH_FULL").is_ok() {
        BenchMode::Full
    } else {
        BenchMode::Quick
    }
}

/// Size preset for benches: quick (CI-friendly) or full (paper-scale shapes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchMode {
    /// Reduced problem sizes; finishes in seconds.
    Quick,
    /// Paper-scale problem sizes.
    Full,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_median_odd_even() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        let s = summarize(&[4.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn run_counts_iterations() {
        let b = Bench::new(1, 5);
        let s = b.run(|| 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.p95);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("us"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }

    #[test]
    fn gflops_computed_from_median() {
        let s = Sample { median: 0.5, mean: 0.5, p95: 0.5, min: 0.5, iters: 1 };
        assert!((s.gflops(1e9) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn json_report_serializes_and_escapes() {
        let mut r = JsonReport::new("unit");
        assert!(r.is_empty());
        r.row(&[("label", "a\"b\\c".into()), ("value", 1.5f64.into()), ("n", 3usize.into())]);
        r.row(&[("value", f64::NAN.into())]);
        assert_eq!(r.len(), 2);
        let s = r.to_json();
        assert!(s.starts_with("[\n"), "{s}");
        assert!(s.contains("\"label\": \"a\\\"b\\\\c\""), "{s}");
        assert!(s.contains("\"value\": 1.5"), "{s}");
        assert!(s.contains("\"n\": 3"), "{s}");
        assert!(s.contains("null"), "{s}");
        assert!(s.trim_end().ends_with(']'), "{s}");
    }

    #[test]
    fn json_report_writes_file() {
        let dir = std::env::temp_dir();
        let mut r = JsonReport::new("benchkit-test");
        r.row(&[("x", 1.0f64.into())]);
        let path = r.write_in(&dir).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"x\": 1"), "{body}");
        let _ = std::fs::remove_file(path);
    }
}
