//! A small bounded multi-producer/multi-consumer channel.
//!
//! `std::sync::mpsc` receivers cannot be cloned, and crossbeam is not in
//! the offline vendor set — but the serving front-end needs N engine
//! workers pulling from one queue, blocking sends for backpressure, and
//! deadline-aware receives for batch formation. This is the minimal
//! Mutex + Condvar implementation of exactly that.
//!
//! Close semantics: the channel closes when every [`Sender`] *or* every
//! [`Receiver`] is dropped. Closed sends fail; receives drain the queue
//! first, then report closure.

use std::collections::VecDeque;
use std::fmt;
use std::time::Instant;

use super::sync::{Arc, Condvar, Mutex};

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receivers: usize,
    closed: bool,
}

struct Shared<T> {
    cap: usize,
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Shared<T> {
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Create a bounded channel with capacity `cap` (at least 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        cap: cap.max(1),
        state: Mutex::new(State {
            queue: VecDeque::new(),
            senders: 1,
            receivers: 1,
            closed: false,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

/// The value returned to a sender whose channel has closed.
pub struct SendError<T>(pub T);

impl<T> fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("SendError(channel closed)")
    }
}

/// The value returned by a failed [`Sender::try_send`], carrying the
/// rejected item so open-loop producers can account for it.
pub enum TrySendError<T> {
    /// The queue was at capacity; the caller may retry or shed the item.
    Full(T),
    /// The channel is closed; no retry can succeed.
    Closed(T),
}

impl<T> fmt::Debug for TrySendError<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrySendError::Full(_) => f.write_str("TrySendError::Full"),
            TrySendError::Closed(_) => f.write_str("TrySendError::Closed"),
        }
    }
}

/// Outcome of a deadline-bounded receive.
#[derive(Debug)]
pub enum Received<T> {
    /// An item arrived before the deadline.
    Item(T),
    /// The deadline passed with the queue empty.
    TimedOut,
    /// The channel is closed and drained.
    Closed,
}

/// Producer half; cloneable.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Sender<T> {
    /// Blocking send; parks while the queue is full (backpressure). Returns
    /// the value if the channel closed before it could be enqueued.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError(value));
            }
            if st.queue.len() < self.shared.cap {
                st.queue.push_back(value);
                drop(st);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send: enqueue if there is room, otherwise return the
    /// value immediately. Open-loop producers (the arrivals bench, the
    /// admission-controlled submit path) use this so a saturated queue
    /// surfaces as an accountable failure instead of silently turning the
    /// producer closed-loop (coordinated omission).
    pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(value));
        }
        if st.queue.len() >= self.shared.cap {
            return Err(TrySendError::Full(value));
        }
        st.queue.push_back(value);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Items currently queued (a queue-depth gauge, racy by nature).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let last = {
            let mut st = self.shared.state.lock().unwrap();
            st.senders -= 1;
            st.senders == 0
        };
        if last {
            self.shared.close();
        }
    }
}

/// Consumer half; cloneable (each item is delivered to exactly one receiver).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once the channel is closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a deadline: parks until an item arrives, the channel
    /// closes, or `deadline` passes — whichever comes first.
    pub fn recv_deadline(&self, deadline: Instant) -> Received<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Received::Item(v);
            }
            if st.closed {
                return Received::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Received::TimedOut;
            }
            let (guard, timeout) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                // The timeout and a racing send can both fire: the send
                // wins if it already enqueued (exactly-once delivery must
                // not drop it), otherwise report the timeout rather than
                // re-deriving it from the wall clock — under the loom
                // model, timeouts are scheduler decisions, not clock
                // reads.
                if let Some(v) = st.queue.pop_front() {
                    drop(st);
                    self.shared.not_full.notify_one();
                    return Received::Item(v);
                }
                if st.closed {
                    return Received::Closed;
                }
                return Received::TimedOut;
            }
        }
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let last = {
            let mut st = self.shared.state.lock().unwrap();
            st.receivers -= 1;
            st.receivers == 0
        };
        if last {
            self.shared.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn fifo_within_capacity() {
        let (tx, rx) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.len(), 4);
        for i in 0..4 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn recv_returns_none_after_senders_drop() {
        let (tx, rx) = bounded(2);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(7)); // drains before reporting closure
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_receivers_drop() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn full_queue_blocks_sender_until_recv() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = std::thread::spawn(move || {
            tx.send(2).unwrap(); // parks until the first item is consumed
            tx.send(3).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
        t.join().unwrap();
    }

    #[test]
    fn try_send_full_then_closed() {
        let (tx, rx) = bounded(1);
        assert!(tx.try_send(1).is_ok());
        match tx.try_send(2) {
            Err(TrySendError::Full(v)) => assert_eq!(v, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(rx.recv(), Some(1));
        assert!(tx.try_send(3).is_ok(), "space freed by recv");
        drop(rx);
        match tx.try_send(4) {
            Err(TrySendError::Closed(v)) => assert_eq!(v, 4),
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn recv_deadline_times_out_then_delivers() {
        let (tx, rx) = bounded(1);
        let got = rx.recv_deadline(Instant::now() + Duration::from_millis(15));
        assert!(matches!(got, Received::TimedOut));
        tx.send(9).unwrap();
        let got = rx.recv_deadline(Instant::now() + Duration::from_secs(5));
        assert!(matches!(got, Received::Item(9)));
        drop(tx);
        let got = rx.recv_deadline(Instant::now() + Duration::from_millis(5));
        assert!(matches!(got, Received::Closed));
    }

    #[test]
    fn multi_consumer_delivers_each_item_once() {
        let (tx, rx) = bounded(8);
        let total = Arc::new(AtomicUsize::new(0));
        let count = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                let total = total.clone();
                let count = count.clone();
                std::thread::spawn(move || {
                    while let Some(v) = rx.recv() {
                        total.fetch_add(v, Ordering::SeqCst);
                        count.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        drop(rx);
        for i in 1..=100usize {
            tx.send(i).unwrap();
        }
        drop(tx);
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 100);
        assert_eq!(total.load(Ordering::SeqCst), (1..=100).sum());
    }
}
