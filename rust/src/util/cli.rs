//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut args = Args::default();
        let items: Vec<String> = iter.into_iter().collect();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    args.flags.insert(stripped.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the process arguments.
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    /// True if `--name` was given (as a bare flag or with a truthy value).
    pub fn flag(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// String value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String value of `--name` or a default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parse `--name` as `T` or return `default`. Panics with a clear message
    /// when the value is present but malformed.
    pub fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid value for --{name}: {v:?} ({e})")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse(&["--steps", "100", "--lr=0.5", "pos1"]);
        assert_eq!(a.num::<usize>("steps", 0), 100);
        assert_eq!(a.num::<f64>("lr", 0.0), 0.5);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["--verbose", "--quick"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("quick"));
        assert!(!a.flag("absent"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.num::<usize>("n", 7), 7);
        assert_eq!(a.get_or("mode", "x"), "x");
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn malformed_number_panics() {
        let a = parse(&["--n", "abc"]);
        let _ = a.num::<usize>("n", 0);
    }
}
