//! A minimal in-tree loom-style model checker for the crate's hand-rolled
//! sync primitives.
//!
//! The real `loom` crate is not in the offline vendor set, so this module
//! provides the subset the repo needs: drop-in `Mutex` / `Condvar` /
//! `atomic` / `thread` types (re-exported through [`crate::util::sync`])
//! plus a deterministic scheduler that explores thread interleavings
//! exhaustively up to a preemption bound (CHESS-style).
//!
//! Outside a [`model`] run the types delegate straight to `std` — a
//! `Mutex` is a `std::sync::Mutex` plus one cold pointer-sized id cell —
//! so ordinary tests and production builds behave (and perform) exactly
//! as before. Inside `model(|| ...)` every sync operation becomes a
//! *scheduling point*: the checker serializes all threads onto one
//! logical timeline, records each nondeterministic choice, and re-runs
//! the closure under every distinct schedule (depth-first over the
//! decision tree, bounded by [`ModelOptions`]).
//!
//! Known, deliberate limitations (documented in
//! `runtime/README.md` § Concurrency invariants):
//!
//! * Sequential consistency only — weak-memory reorderings are not
//!   modeled (all `Ordering`s are treated as `SeqCst`).
//! * `notify_one` wakes the longest-waiting thread (FIFO) instead of
//!   branching over every waiter — a state-space reduction.
//! * No spurious condvar wakeups; `wait_timeout` *timeouts* are modeled
//!   as scheduler choices instead (bounded by
//!   [`ModelOptions::timeout_budget`], and always taken when nothing
//!   else can run, so lost-wakeup bugs surface as deadlocks).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::time::{Duration, Instant};

/// Monotonic execution generation, used to re-key model object ids when a
/// `Mutex`/`Condvar` value outlives one exploration iteration.
static EXEC_GEN: StdAtomicU64 = StdAtomicU64::new(1);

/// Panic message used to tear threads down after the model records a
/// failure; the runner re-raises the *real* message from [`Inner::failed`].
const ABORT_MSG: &str = "loom model aborted";

thread_local! {
    static TLS: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// Per-thread handle onto the active model execution.
#[derive(Clone)]
struct Ctx {
    exec: StdArc<Exec>,
    tid: usize,
}

fn ctx() -> Option<Ctx> {
    TLS.with(|t| t.borrow().clone())
}

/// Explicit scheduling point: inside a model run, yield to the scheduler
/// (which may switch to any runnable thread); outside, a no-op.
pub(crate) fn sched_point() {
    if let Some(cx) = ctx() {
        cx.exec.transition(cx.tid, None);
    }
}

/// Bounds on the schedule exploration.
#[derive(Clone, Debug)]
pub struct ModelOptions {
    /// Maximum number of *preemptive* context switches per execution
    /// (switches away from a thread that could have kept running).
    /// `None` = unbounded (full exhaustive search). CHESS showed small
    /// bounds (2) find almost all real bugs while taming the state space.
    pub preemption_bound: Option<usize>,
    /// How many *optional* condvar-timeout wakeups the scheduler may
    /// inject per execution. Forced timeouts (taken when no thread is
    /// runnable) are always allowed and do not count.
    pub timeout_budget: usize,
    /// Stop exploring after this many schedules (a safety valve, not a
    /// soundness bound — hitting it means coverage was truncated).
    pub max_iterations: usize,
    /// Abort an execution whose scheduling-point count exceeds this
    /// (livelock guard).
    pub max_steps: usize,
    /// Optional wall-clock budget for the whole exploration; exceeded =>
    /// stop early and return how many schedules were covered.
    pub time_budget: Option<Duration>,
}

impl Default for ModelOptions {
    fn default() -> Self {
        ModelOptions {
            preemption_bound: Some(2),
            timeout_budget: 2,
            max_iterations: 200_000,
            max_steps: 20_000,
            time_budget: None,
        }
    }
}

impl ModelOptions {
    /// Run `f` under every schedule permitted by these bounds. Panics on
    /// the first failing schedule (deadlock, livelock, nondeterminism, or
    /// a panic inside `f`), printing the decision path that reached it.
    /// Returns the number of schedules explored.
    pub fn check<F>(&self, f: F) -> usize
    where
        F: Fn() + Send + Sync + 'static,
    {
        let f = StdArc::new(f);
        let started = Instant::now();
        let mut prefix: Vec<Choice> = Vec::new();
        let mut iterations = 0usize;
        loop {
            iterations += 1;
            let exec = Exec::new(self.clone(), prefix.clone());
            let root_cx = Ctx { exec: StdArc::clone(&exec), tid: 0 };
            let fr = StdArc::clone(&f);
            let handle = std::thread::Builder::new()
                .name("loom-root".into())
                .spawn(move || {
                    let _g = CtxGuard::install(root_cx);
                    fr();
                })
                .expect("loom: failed to spawn root thread");
            let root_res = handle.join();
            exec.wait_all_done();
            let (failed, path, any_panicked) = exec.outcome();
            if let Some(msg) = failed {
                panic!("loom model failed: {msg}\nschedule: {path:?}");
            }
            if let Err(payload) = root_res {
                eprintln!("loom: root thread panicked on schedule {path:?}");
                std::panic::resume_unwind(payload);
            }
            if any_panicked {
                panic!("loom: a spawned thread panicked on schedule {path:?}");
            }
            if iterations >= self.max_iterations {
                eprintln!(
                    "loom: stopping after {iterations} schedules (max_iterations); \
                     coverage truncated"
                );
                return iterations;
            }
            if let Some(budget) = self.time_budget {
                if started.elapsed() >= budget {
                    eprintln!(
                        "loom: stopping after {iterations} schedules (time budget); \
                         coverage truncated"
                    );
                    return iterations;
                }
            }
            // Depth-first backtrack: advance the last choice that still has
            // unexplored options; when none remains the space is exhausted.
            prefix = path;
            loop {
                match prefix.last_mut() {
                    Some(last) if last.chosen + 1 < last.total => {
                        last.chosen += 1;
                        break;
                    }
                    Some(_) => {
                        prefix.pop();
                    }
                    None => return iterations,
                }
            }
        }
    }
}

/// Run `f` under [`ModelOptions::default`] bounds. See
/// [`ModelOptions::check`].
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    ModelOptions::default().check(f);
}

/// What a modeled thread is doing, from the scheduler's point of view.
#[derive(Clone, Debug, PartialEq)]
enum State {
    /// Runnable (possibly the active thread).
    Ready,
    /// Blocked acquiring model mutex `.0`.
    Mutex(usize),
    /// Parked on model condvar `cv`; `timeoutable` waits may be woken by
    /// a scheduler-injected timeout.
    Condvar { cv: usize, timeoutable: bool },
    /// Blocked joining thread `.0`.
    Join(usize),
    /// Exited (normally or by panic).
    Finished,
}

struct ThreadState {
    state: State,
    /// Set when the scheduler wakes a `Condvar` wait via timeout; consumed
    /// by the waiter to report `timed_out()`.
    timed_out: bool,
    panicked: bool,
}

fn new_thread_state() -> ThreadState {
    ThreadState { state: State::Ready, timed_out: false, panicked: false }
}

struct MutexSt {
    held: bool,
}

struct CvSt {
    /// FIFO wait queue of thread ids.
    waiters: Vec<usize>,
}

/// One recorded nondeterministic decision: option `chosen` out of `total`.
#[derive(Clone, Debug)]
struct Choice {
    chosen: usize,
    total: usize,
}

/// A schedulable option at a decision point.
enum Opt {
    /// Let thread `.0` (currently `Ready`) run.
    Run(usize),
    /// Wake thread `.0` from a timeoutable condvar wait via timeout.
    Timeout(usize),
}

struct Inner {
    threads: Vec<ThreadState>,
    mutexes: Vec<MutexSt>,
    condvars: Vec<CvSt>,
    /// The one thread allowed to run right now (`usize::MAX` once all
    /// threads have finished).
    active: usize,
    /// Replay prefix plus choices recorded so far this execution.
    path: Vec<Choice>,
    cursor: usize,
    preemptions: usize,
    timeouts: usize,
    steps: usize,
    failed: Option<String>,
}

/// One model execution: the scheduler state plus the master lock/condvar
/// every modeled thread parks on.
struct Exec {
    opts: ModelOptions,
    gen: u64,
    m: StdMutex<Inner>,
    cv: StdCondvar,
}

fn describe(g: &Inner) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for (i, t) in g.threads.iter().enumerate() {
        let _ = write!(s, "[t{} {:?}] ", i, t.state);
    }
    s
}

fn pop_front_vec(v: &mut Vec<usize>) -> Option<usize> {
    if v.is_empty() {
        None
    } else {
        Some(v.remove(0))
    }
}

impl Exec {
    fn new(opts: ModelOptions, path: Vec<Choice>) -> StdArc<Exec> {
        let gen = EXEC_GEN.fetch_add(1, StdOrdering::SeqCst) + 1;
        StdArc::new(Exec {
            opts,
            gen,
            m: StdMutex::new(Inner {
                threads: vec![new_thread_state()],
                mutexes: Vec::new(),
                condvars: Vec::new(),
                active: 0,
                path,
                cursor: 0,
                preemptions: 0,
                timeouts: 0,
                steps: 0,
                failed: None,
            }),
            cv: StdCondvar::new(),
        })
    }

    fn alloc_mutex(&self) -> usize {
        let mut g = self.m.lock().unwrap();
        g.mutexes.push(MutexSt { held: false });
        g.mutexes.len() - 1
    }

    fn alloc_condvar(&self) -> usize {
        let mut g = self.m.lock().unwrap();
        g.condvars.push(CvSt { waiters: Vec::new() });
        g.condvars.len() - 1
    }

    /// Record a failure, wake everyone, and unwind the calling thread.
    /// Never double-panics: during unwinding it only sets the flag.
    fn abort(&self, mut g: StdMutexGuard<'_, Inner>, msg: String) {
        if g.failed.is_none() {
            g.failed = Some(msg);
        }
        drop(g);
        self.cv.notify_all();
        if !std::thread::panicking() {
            panic!("{}", ABORT_MSG);
        }
    }

    /// Park until this thread is scheduled (active + Ready) or the
    /// execution fails.
    fn park(&self, mut g: StdMutexGuard<'_, Inner>, me: usize) {
        loop {
            if g.failed.is_some() {
                drop(g);
                if !std::thread::panicking() {
                    panic!("{}", ABORT_MSG);
                }
                return;
            }
            if g.active == me && g.threads[me].state == State::Ready {
                return;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Move `me` into `state`, pick the next thread to run, and park until
    /// `me` is scheduled again. `State::Ready` = a pure scheduling point.
    fn block_on(&self, mut g: StdMutexGuard<'_, Inner>, me: usize, state: State) {
        if g.failed.is_some() {
            drop(g);
            if !std::thread::panicking() {
                panic!("{}", ABORT_MSG);
            }
            return;
        }
        g.steps += 1;
        if g.steps > self.opts.max_steps {
            let max = self.opts.max_steps;
            self.abort(g, format!("execution exceeded {max} scheduling points (livelock?)"));
            return;
        }
        g.threads[me].state = state;
        match self.pick_next(&mut g, me) {
            Ok(()) => {
                self.cv.notify_all();
                self.park(g, me);
            }
            Err(msg) => self.abort(g, msg),
        }
    }

    /// Scheduling point: optionally move `me` to `new_state` (default:
    /// stay `Ready`) and let the scheduler choose who runs next.
    fn transition(&self, me: usize, new_state: Option<State>) {
        let g = self.m.lock().unwrap();
        self.block_on(g, me, new_state.unwrap_or(State::Ready));
    }

    /// Choose the next active thread, consuming/extending the decision
    /// path. `Err` = deadlock or nondeterministic replay.
    fn pick_next(&self, g: &mut Inner, me: usize) -> Result<(), String> {
        let mut opts: Vec<Opt> = Vec::new();
        let mut timeout_opts: Vec<Opt> = Vec::new();
        for (i, t) in g.threads.iter().enumerate() {
            match t.state {
                State::Ready => opts.push(Opt::Run(i)),
                State::Condvar { timeoutable: true, .. } => timeout_opts.push(Opt::Timeout(i)),
                _ => {}
            }
        }
        let mut forced_timeout = false;
        if opts.is_empty() {
            forced_timeout = true;
            opts = timeout_opts;
        } else if g.timeouts < self.opts.timeout_budget {
            opts.extend(timeout_opts);
        }
        if opts.is_empty() {
            if g.threads.iter().all(|t| t.state == State::Finished) {
                g.active = usize::MAX;
                return Ok(());
            }
            return Err(format!("deadlock detected: {}", describe(g)));
        }
        let me_runnable = me < g.threads.len() && g.threads[me].state == State::Ready;
        if me_runnable {
            if let Some(bound) = self.opts.preemption_bound {
                if g.preemptions >= bound {
                    // Budget exhausted: keep running the current thread.
                    g.active = me;
                    return Ok(());
                }
            }
        }
        let total = opts.len();
        let idx = if total == 1 {
            0
        } else {
            let cursor = g.cursor;
            if cursor < g.path.len() {
                if g.path[cursor].total != total {
                    return Err(format!(
                        "nondeterministic execution: replay expected {} options at decision {}, \
                         found {}",
                        g.path[cursor].total, cursor, total
                    ));
                }
                g.cursor += 1;
                g.path[cursor].chosen
            } else {
                g.path.push(Choice { chosen: 0, total });
                g.cursor += 1;
                0
            }
        };
        match opts[idx] {
            Opt::Run(tid) => {
                if me_runnable && tid != me {
                    g.preemptions += 1;
                }
                g.active = tid;
            }
            Opt::Timeout(tid) => {
                if me_runnable {
                    g.preemptions += 1;
                }
                if !forced_timeout {
                    g.timeouts += 1;
                }
                if let State::Condvar { cv, .. } = g.threads[tid].state {
                    g.condvars[cv].waiters.retain(|&w| w != tid);
                }
                g.threads[tid].timed_out = true;
                g.threads[tid].state = State::Ready;
                g.active = tid;
            }
        }
        Ok(())
    }

    /// Acquire model mutex `mid` for thread `me`. `first_yield` inserts a
    /// scheduling point *before* the acquire (so lock order races are
    /// explored); re-acquisition after a condvar wait skips it.
    fn mutex_lock(&self, me: usize, mid: usize, first_yield: bool) {
        if first_yield {
            self.transition(me, None);
        }
        loop {
            let mut g = self.m.lock().unwrap();
            if g.failed.is_some() {
                drop(g);
                if !std::thread::panicking() {
                    panic!("{}", ABORT_MSG);
                }
                return;
            }
            if !g.mutexes[mid].held {
                g.mutexes[mid].held = true;
                return;
            }
            self.block_on(g, me, State::Mutex(mid));
        }
    }

    /// Release model mutex `mid`, making its blocked acquirers runnable.
    fn mutex_unlock(&self, mid: usize) {
        let mut g = match self.m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.mutexes[mid].held = false;
        for t in g.threads.iter_mut() {
            if t.state == State::Mutex(mid) {
                t.state = State::Ready;
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Atomically (under the master lock) register `me` on condvar `cvid`,
    /// release mutex `mid`, and park — the indivisibility that makes lost
    /// wakeups impossible for a correctly locked wait. Returns whether the
    /// wake was a timeout.
    fn condvar_wait(&self, me: usize, cvid: usize, mid: usize, timeoutable: bool) -> bool {
        {
            let mut g = self.m.lock().unwrap();
            if g.failed.is_some() {
                drop(g);
                if !std::thread::panicking() {
                    panic!("{}", ABORT_MSG);
                }
                return true;
            }
            g.condvars[cvid].waiters.push(me);
            g.threads[me].timed_out = false;
            g.mutexes[mid].held = false;
            for t in g.threads.iter_mut() {
                if t.state == State::Mutex(mid) {
                    t.state = State::Ready;
                }
            }
            self.block_on(g, me, State::Condvar { cv: cvid, timeoutable });
        }
        let mut g = match self.m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let timed_out = g.threads[me].timed_out;
        g.threads[me].timed_out = false;
        timed_out
    }

    /// Wake waiter(s) on condvar `cvid`. FIFO order (see module docs).
    fn condvar_notify(&self, cvid: usize, all: bool) {
        let mut g = match self.m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        while let Some(tid) = pop_front_vec(&mut g.condvars[cvid].waiters) {
            g.threads[tid].timed_out = false;
            g.threads[tid].state = State::Ready;
            if !all {
                break;
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Register a new modeled thread (Ready but parked until scheduled).
    fn register_thread(&self) -> usize {
        let mut g = self.m.lock().unwrap();
        g.threads.push(new_thread_state());
        g.threads.len() - 1
    }

    /// First thing a freshly spawned modeled thread does: park until the
    /// scheduler picks it.
    fn thread_begin(&self, me: usize) {
        let g = self.m.lock().unwrap();
        self.park(g, me);
    }

    /// Mark `me` finished and hand the schedule to someone else. Runs in
    /// drop/unwind context, so it must never panic.
    fn thread_finish(&self, me: usize, panicked: bool) {
        let mut g = match self.m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.threads[me].panicked = panicked;
        g.threads[me].state = State::Finished;
        for t in g.threads.iter_mut() {
            if t.state == State::Join(me) {
                t.state = State::Ready;
            }
        }
        if g.failed.is_none() {
            if let Err(msg) = self.pick_next(&mut g, me) {
                g.failed = Some(msg);
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Block `me` until `target` finishes.
    fn join_thread(&self, me: usize, target: usize) {
        loop {
            let g = self.m.lock().unwrap();
            if g.failed.is_some() {
                drop(g);
                if !std::thread::panicking() {
                    panic!("{}", ABORT_MSG);
                }
                return;
            }
            if g.threads[target].state == State::Finished {
                return;
            }
            self.block_on(g, me, State::Join(target));
        }
    }

    /// Runner-side: wait until every modeled thread has finished (or the
    /// execution failed, in which case threads unwind on their own).
    fn wait_all_done(&self) {
        let mut g = match self.m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        loop {
            if g.failed.is_some() {
                return;
            }
            if g.threads.iter().all(|t| t.state == State::Finished) {
                return;
            }
            g = match self.cv.wait(g) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    fn outcome(&self) -> (Option<String>, Vec<Choice>, bool) {
        let g = match self.m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        (g.failed.clone(), g.path.clone(), g.threads.iter().any(|t| t.panicked))
    }
}

/// Installs the thread-local model context on construction and reports
/// thread completion (normal or panicking) on drop.
struct CtxGuard;

impl CtxGuard {
    fn install(cx: Ctx) -> CtxGuard {
        let exec = StdArc::clone(&cx.exec);
        let tid = cx.tid;
        TLS.with(|t| *t.borrow_mut() = Some(cx));
        exec.thread_begin(tid);
        CtxGuard
    }
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        let cx = TLS.with(|t| t.borrow_mut().take());
        if let Some(cx) = cx {
            cx.exec.thread_finish(cx.tid, std::thread::panicking());
        }
    }
}

/// Model-aware drop-ins for the `std::sync` types the crate uses; see the
/// module docs. Re-exported through [`crate::util::sync`] under
/// `--features loom`.
pub mod sync {
    pub use std::sync::Arc;
    use std::sync::{
        Condvar as StdCondvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard,
        PoisonError,
    };
    use std::time::Duration;

    use super::{ctx, Ctx};

    /// Lazily assigned per-execution model object id (see `EXEC_GEN`).
    struct ObjCell {
        gen: u64,
        id: usize,
    }

    const fn obj_cell() -> StdMutex<ObjCell> {
        StdMutex::new(ObjCell { gen: 0, id: 0 })
    }

    /// Model-aware mutex: delegates to [`std::sync::Mutex`] outside a
    /// model run.
    pub struct Mutex<T> {
        cell: StdMutex<ObjCell>,
        inner: StdMutex<T>,
    }

    /// Guard for [`Mutex`]; releases the model lock (after the real one)
    /// on drop.
    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<StdMutexGuard<'a, T>>,
        model: Option<(Ctx, usize)>,
    }

    impl<T> Mutex<T> {
        /// New unlocked mutex.
        pub const fn new(value: T) -> Mutex<T> {
            Mutex { cell: obj_cell(), inner: StdMutex::new(value) }
        }

        fn model_id(&self, cx: &Ctx) -> usize {
            let mut c = self.cell.lock().unwrap();
            if c.gen != cx.exec.gen {
                c.id = cx.exec.alloc_mutex();
                c.gen = cx.exec.gen;
            }
            c.id
        }

        /// Acquire; a scheduling point inside a model run.
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            let model = match ctx() {
                Some(cx) => {
                    let mid = self.model_id(&cx);
                    cx.exec.mutex_lock(cx.tid, mid, true);
                    Some((cx, mid))
                }
                None => None,
            };
            // The inner std lock is uncontended here: inside a model run
            // only the logically active thread reaches it, outside one it
            // is the real lock.
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), model }),
                Err(poisoned) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(poisoned.into_inner()),
                    model,
                })),
            }
        }

        /// Re-acquire after a condvar wait (no pre-acquire scheduling
        /// point: the wait itself was one).
        fn lock_after_wait(&self, cx: Ctx, mid: usize) -> LockResult<MutexGuard<'_, T>> {
            cx.exec.mutex_lock(cx.tid, mid, false);
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), model: Some((cx, mid)) }),
                Err(poisoned) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(poisoned.into_inner()),
                    model: Some((cx, mid)),
                })),
            }
        }

        /// Consume the mutex, returning the value.
        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }
    }

    impl<T: Default> Default for Mutex<T> {
        fn default() -> Self {
            Mutex::new(T::default())
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("loom MutexGuard used after dismantle")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("loom MutexGuard used after dismantle")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // Real unlock first, then the model unlock hands the mutex to
            // the next modeled acquirer.
            if let Some(g) = self.inner.take() {
                drop(g);
            }
            if let Some((cx, mid)) = self.model.take() {
                cx.exec.mutex_unlock(mid);
            }
        }
    }

    /// Result of [`Condvar::wait_timeout`].
    pub struct WaitTimeoutResult(bool);

    impl WaitTimeoutResult {
        /// True when the wait ended by timeout rather than notification.
        pub fn timed_out(&self) -> bool {
            self.0
        }
    }

    /// Model-aware condition variable; delegates to
    /// [`std::sync::Condvar`] outside a model run.
    pub struct Condvar {
        cell: StdMutex<ObjCell>,
        inner: StdCondvar,
    }

    impl Condvar {
        /// New condvar.
        pub const fn new() -> Condvar {
            Condvar { cell: obj_cell(), inner: StdCondvar::new() }
        }

        fn model_id(&self, cx: &Ctx) -> usize {
            let mut c = self.cell.lock().unwrap();
            if c.gen != cx.exec.gen {
                c.id = cx.exec.alloc_condvar();
                c.gen = cx.exec.gen;
            }
            c.id
        }

        /// Atomically release the guard and park until notified.
        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            let lock = guard.lock;
            match guard.model.take() {
                Some((cx, mid)) => {
                    let cvid = self.model_id(&cx);
                    drop(guard.inner.take());
                    drop(guard);
                    cx.exec.condvar_wait(cx.tid, cvid, mid, false);
                    lock.lock_after_wait(cx, mid)
                }
                None => {
                    let inner = guard.inner.take().expect("loom MutexGuard used after dismantle");
                    drop(guard);
                    match self.inner.wait(inner) {
                        Ok(g) => Ok(MutexGuard { lock, inner: Some(g), model: None }),
                        Err(poisoned) => Err(PoisonError::new(MutexGuard {
                            lock,
                            inner: Some(poisoned.into_inner()),
                            model: None,
                        })),
                    }
                }
            }
        }

        /// [`Condvar::wait`] with a timeout. Inside a model run the
        /// duration is ignored: timeouts are scheduler choices (see the
        /// module docs).
        pub fn wait_timeout<'a, T>(
            &self,
            mut guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            let lock = guard.lock;
            match guard.model.take() {
                Some((cx, mid)) => {
                    let cvid = self.model_id(&cx);
                    drop(guard.inner.take());
                    drop(guard);
                    let timed_out = cx.exec.condvar_wait(cx.tid, cvid, mid, true);
                    match lock.lock_after_wait(cx, mid) {
                        Ok(g) => Ok((g, WaitTimeoutResult(timed_out))),
                        Err(poisoned) => Err(PoisonError::new((
                            poisoned.into_inner(),
                            WaitTimeoutResult(timed_out),
                        ))),
                    }
                }
                None => {
                    let inner = guard.inner.take().expect("loom MutexGuard used after dismantle");
                    drop(guard);
                    match self.inner.wait_timeout(inner, dur) {
                        Ok((g, to)) => Ok((
                            MutexGuard { lock, inner: Some(g), model: None },
                            WaitTimeoutResult(to.timed_out()),
                        )),
                        Err(poisoned) => {
                            let (g, to) = poisoned.into_inner();
                            Err(PoisonError::new((
                                MutexGuard { lock, inner: Some(g), model: None },
                                WaitTimeoutResult(to.timed_out()),
                            )))
                        }
                    }
                }
            }
        }

        /// Wake one waiter (FIFO inside a model run).
        pub fn notify_one(&self) {
            match ctx() {
                Some(cx) => {
                    let cvid = self.model_id(&cx);
                    cx.exec.condvar_notify(cvid, false);
                }
                None => self.inner.notify_one(),
            }
        }

        /// Wake all waiters.
        pub fn notify_all(&self) {
            match ctx() {
                Some(cx) => {
                    let cvid = self.model_id(&cx);
                    cx.exec.condvar_notify(cvid, true);
                }
                None => self.inner.notify_all(),
            }
        }
    }

    impl Default for Condvar {
        fn default() -> Self {
            Condvar::new()
        }
    }

    /// Model-aware atomics: every operation is a scheduling point inside
    /// a model run (sequential consistency — see the module docs).
    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        use std::sync::atomic as std_atomic;

        use super::super::sched_point;

        macro_rules! model_atomic {
            ($name:ident, $std:ty, $prim:ty) => {
                /// Model-aware atomic (see [`self`] module docs).
                #[derive(Debug, Default)]
                pub struct $name(pub(crate) $std);

                impl $name {
                    /// New atomic holding `v`.
                    pub const fn new(v: $prim) -> Self {
                        Self(<$std>::new(v))
                    }

                    /// Atomic load (a scheduling point in a model run).
                    pub fn load(&self, o: Ordering) -> $prim {
                        sched_point();
                        self.0.load(o)
                    }

                    /// Atomic store (a scheduling point in a model run).
                    pub fn store(&self, v: $prim, o: Ordering) {
                        sched_point();
                        self.0.store(v, o)
                    }

                    /// Atomic swap (a scheduling point in a model run).
                    pub fn swap(&self, v: $prim, o: Ordering) -> $prim {
                        sched_point();
                        self.0.swap(v, o)
                    }

                    /// Atomic compare-exchange (a scheduling point in a
                    /// model run).
                    pub fn compare_exchange(
                        &self,
                        current: $prim,
                        new: $prim,
                        success: Ordering,
                        failure: Ordering,
                    ) -> Result<$prim, $prim> {
                        sched_point();
                        self.0.compare_exchange(current, new, success, failure)
                    }
                }
            };
        }

        macro_rules! model_atomic_arith {
            ($name:ident, $prim:ty) => {
                impl $name {
                    /// Atomic add (a scheduling point in a model run).
                    pub fn fetch_add(&self, v: $prim, o: Ordering) -> $prim {
                        sched_point();
                        self.0.fetch_add(v, o)
                    }

                    /// Atomic sub (a scheduling point in a model run).
                    pub fn fetch_sub(&self, v: $prim, o: Ordering) -> $prim {
                        sched_point();
                        self.0.fetch_sub(v, o)
                    }

                    /// Atomic max (a scheduling point in a model run).
                    pub fn fetch_max(&self, v: $prim, o: Ordering) -> $prim {
                        sched_point();
                        self.0.fetch_max(v, o)
                    }

                    /// Atomic min (a scheduling point in a model run).
                    pub fn fetch_min(&self, v: $prim, o: Ordering) -> $prim {
                        sched_point();
                        self.0.fetch_min(v, o)
                    }
                }
            };
        }

        model_atomic!(AtomicUsize, std_atomic::AtomicUsize, usize);
        model_atomic_arith!(AtomicUsize, usize);
        model_atomic!(AtomicU64, std_atomic::AtomicU64, u64);
        model_atomic_arith!(AtomicU64, u64);
        model_atomic!(AtomicU32, std_atomic::AtomicU32, u32);
        model_atomic_arith!(AtomicU32, u32);
        model_atomic!(AtomicBool, std_atomic::AtomicBool, bool);

        impl AtomicBool {
            /// Atomic or (a scheduling point in a model run).
            pub fn fetch_or(&self, v: bool, o: Ordering) -> bool {
                sched_point();
                self.0.fetch_or(v, o)
            }

            /// Atomic and (a scheduling point in a model run).
            pub fn fetch_and(&self, v: bool, o: Ordering) -> bool {
                sched_point();
                self.0.fetch_and(v, o)
            }
        }
    }
}

/// Model-aware drop-ins for the `std::thread` items the crate uses.
/// Spawned threads are registered with the scheduler and park until it
/// picks them; outside a model run everything delegates to `std`.
pub mod thread {
    pub use std::thread::available_parallelism;

    use std::io;
    use std::time::Duration;

    use super::{ctx, sched_point, Ctx, CtxGuard};

    /// Model-aware [`std::thread::Builder`].
    pub struct Builder {
        inner: std::thread::Builder,
    }

    impl Builder {
        /// New builder.
        pub fn new() -> Builder {
            Builder { inner: std::thread::Builder::new() }
        }

        /// Name the thread.
        pub fn name(self, name: String) -> Builder {
            Builder { inner: self.inner.name(name) }
        }

        /// Set the stack size.
        pub fn stack_size(self, size: usize) -> Builder {
            Builder { inner: self.inner.stack_size(size) }
        }

        /// Spawn; inside a model run the child registers with the
        /// scheduler and parks until first scheduled (so replay stays
        /// deterministic).
        pub fn spawn<F, T>(self, f: F) -> io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            match ctx() {
                Some(cx) => {
                    let tid = cx.exec.register_thread();
                    let child = Ctx { exec: std::sync::Arc::clone(&cx.exec), tid };
                    let std = self.inner.spawn(move || {
                        let _g = CtxGuard::install(child);
                        f()
                    })?;
                    Ok(JoinHandle { std, model: Some(tid) })
                }
                None => {
                    let std = self.inner.spawn(f)?;
                    Ok(JoinHandle { std, model: None })
                }
            }
        }
    }

    impl Default for Builder {
        fn default() -> Self {
            Builder::new()
        }
    }

    /// Model-aware [`std::thread::JoinHandle`].
    pub struct JoinHandle<T> {
        std: std::thread::JoinHandle<T>,
        /// Model thread id of the child, when spawned inside a model run.
        model: Option<usize>,
    }

    impl<T> JoinHandle<T> {
        /// Join; a blocking scheduling point inside a model run.
        pub fn join(self) -> std::thread::Result<T> {
            if let Some(target) = self.model {
                if let Some(cur) = ctx() {
                    cur.exec.join_thread(cur.tid, target);
                }
            }
            self.std.join()
        }

        /// Whether the thread has finished.
        pub fn is_finished(&self) -> bool {
            self.std.is_finished()
        }
    }

    /// Model-aware [`std::thread::spawn`].
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    /// Model-aware [`std::thread::yield_now`] (a scheduling point).
    pub fn yield_now() {
        if ctx().is_some() {
            sched_point();
        } else {
            std::thread::yield_now();
        }
    }

    /// Model-aware [`std::thread::sleep`]: inside a model run, just a
    /// scheduling point (virtual time).
    pub fn sleep(dur: Duration) {
        if ctx().is_some() {
            sched_point();
        } else {
            std::thread::sleep(dur);
        }
    }
}

#[cfg(test)]
mod tests {
    use std::panic::catch_unwind;
    use std::time::Duration;

    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::{Arc, Condvar, Mutex};
    use super::{thread, ModelOptions};

    fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            String::new()
        }
    }

    #[test]
    fn finds_lost_update() {
        // Unsynchronized load-then-store increment: the model must find
        // the interleaving where one increment is lost.
        let result = catch_unwind(|| {
            ModelOptions::default().check(|| {
                let a = Arc::new(AtomicUsize::new(0));
                let b = Arc::clone(&a);
                let t = thread::spawn(move || {
                    let v = b.load(Ordering::SeqCst);
                    b.store(v + 1, Ordering::SeqCst);
                });
                let v = a.load(Ordering::SeqCst);
                a.store(v + 1, Ordering::SeqCst);
                t.join().unwrap();
                assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
            });
        });
        assert!(result.is_err(), "model must find the lost-update interleaving");
    }

    #[test]
    fn atomic_increment_explores_multiple_schedules_and_passes() {
        let iterations = ModelOptions::default().check(|| {
            let a = Arc::new(AtomicUsize::new(0));
            let b = Arc::clone(&a);
            let t = thread::spawn(move || {
                b.fetch_add(1, Ordering::SeqCst);
            });
            a.fetch_add(1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(a.load(Ordering::SeqCst), 2);
        });
        assert!(iterations > 1, "expected >1 schedule, got {iterations}");
    }

    #[test]
    fn detects_abba_deadlock() {
        let result = catch_unwind(|| {
            ModelOptions::default().check(|| {
                let m1 = Arc::new(Mutex::new(()));
                let m2 = Arc::new(Mutex::new(()));
                let (a1, a2) = (Arc::clone(&m1), Arc::clone(&m2));
                let t = thread::spawn(move || {
                    let g1 = a1.lock().unwrap();
                    let g2 = a2.lock().unwrap();
                    drop(g2);
                    drop(g1);
                });
                let g2 = m2.lock().unwrap();
                let g1 = m1.lock().unwrap();
                drop(g1);
                drop(g2);
                t.join().unwrap();
            });
        });
        let payload = result.expect_err("model must find the ABBA deadlock");
        assert!(
            panic_message(payload.as_ref()).contains("deadlock"),
            "expected a deadlock report"
        );
    }

    #[test]
    fn condvar_handshake_passes() {
        // Correctly locked wait: registration and mutex release are
        // indivisible, so no schedule loses the wakeup.
        ModelOptions::default().check(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let t = thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap() = true;
                cv.notify_one();
            });
            let (m, cv) = &*pair;
            let mut g = m.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            t.join().unwrap();
        });
    }

    #[test]
    fn finds_lost_wakeup_in_check_then_wait_gap() {
        // Classic bug: test the flag in one critical section, wait in a
        // second one. The notify can land in the gap; the model must
        // surface the resulting hang as a deadlock.
        let result = catch_unwind(|| {
            ModelOptions::default().check(|| {
                let pair = Arc::new((Mutex::new(false), Condvar::new()));
                let p2 = Arc::clone(&pair);
                let t = thread::spawn(move || {
                    let (m, cv) = &*p2;
                    *m.lock().unwrap() = true;
                    cv.notify_one();
                });
                let (m, cv) = &*pair;
                let done = { *m.lock().unwrap() };
                if !done {
                    let g = m.lock().unwrap();
                    let g = cv.wait(g).unwrap();
                    assert!(*g);
                }
                t.join().unwrap();
            });
        });
        let payload = result.expect_err("model must find the lost wakeup");
        assert!(
            panic_message(payload.as_ref()).contains("deadlock"),
            "lost wakeup should surface as a deadlock"
        );
    }

    #[test]
    fn wait_timeout_without_notifier_times_out() {
        ModelOptions::default().check(|| {
            let m = Mutex::new(());
            let cv = Condvar::new();
            let g = m.lock().unwrap();
            let (g, to) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
            assert!(to.timed_out(), "no notifier exists, so the wake must be a timeout");
            drop(g);
        });
    }
}
