//! Small self-contained utilities: RNG, thread pool, timing, bench harness,
//! CLI parsing and a mini property-testing helper.
//!
//! The build environment is fully offline with a fixed vendor set (the `xla`
//! crate's dependency tree), so widely-used helpers such as `rand`, `rayon`,
//! `clap` and `criterion` are re-implemented here in the small.

pub mod rng;
pub mod threadpool;
pub mod timer;
pub mod benchkit;
pub mod cli;
pub mod proptest;

pub use rng::Pcg64;
pub use threadpool::ThreadPool;
pub use timer::{Stopwatch, TimeBreakdown};
