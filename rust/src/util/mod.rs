//! Small self-contained utilities: RNG, thread pools, channels, timing,
//! bench harness, CLI parsing and a mini property-testing helper.
//!
//! The build environment is fully offline with a minimal vendor set
//! (`anyhow` only), so widely-used helpers such as `rand`, `rayon`,
//! `crossbeam`, `clap` and `criterion` are re-implemented here in the small.

pub mod channel;
pub mod loom;
pub mod rng;
pub mod sync;
pub mod threadpool;
pub mod timer;
pub mod benchkit;
pub mod cli;
pub mod proptest;

pub use rng::Pcg64;
pub use threadpool::{ThreadPool, WorkerPool};
pub use timer::{Stopwatch, TimeBreakdown};
