//! Mini property-based testing helper (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` randomly-generated inputs and, on
//! failure, greedily shrinks the failing input before panicking with a
//! reproducible seed. Generators are plain closures over [`Pcg64`].

use super::rng::Pcg64;

/// Run `prop` on `cases` inputs drawn by `gen`. On failure, attempt to shrink
/// via `shrink` (which yields candidate smaller inputs) and panic with the
/// minimal failing case and the seed that reproduces it.
pub fn check_with_shrink<T: Clone + std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> bool,
) {
    let seed = std::env::var("STEN_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Pcg64::seeded(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // Greedy shrink loop.
            let mut minimal = input.clone();
            let mut progress = true;
            while progress {
                progress = false;
                for cand in shrink(&minimal) {
                    if !prop(&cand) {
                        minimal = cand;
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property {name} failed at case {case} (seed {seed}).\n original: {input:?}\n minimal: {minimal:?}"
            );
        }
    }
}

/// [`check_with_shrink`] without shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl FnMut(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> bool,
) {
    check_with_shrink(name, cases, gen, |_| Vec::new(), prop);
}

/// Generator helper: random shape with each dim in `[1, max_dim]`.
pub fn gen_shape(rng: &mut Pcg64, rank: usize, max_dim: usize) -> Vec<usize> {
    (0..rank).map(|_| 1 + rng.below(max_dim as u32) as usize).collect()
}

/// Generator helper: vector of `n` uniform floats in `[-1, 1]`.
pub fn gen_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 100, |r| (r.next_f32(), r.next_f32()), |(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "property always-false failed")]
    fn failing_property_panics() {
        check("always-false", 10, |r| r.next_u32(), |_| false);
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        let result = std::panic::catch_unwind(|| {
            check_with_shrink(
                "lt-100",
                100,
                |r| r.below(1000),
                |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
                |&x| x < 100,
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land exactly on the boundary.
        assert!(msg.contains("minimal: 100"), "msg: {msg}");
    }

    #[test]
    fn gen_helpers_in_bounds() {
        let mut rng = Pcg64::seeded(1);
        let shape = gen_shape(&mut rng, 3, 8);
        assert_eq!(shape.len(), 3);
        assert!(shape.iter().all(|&d| (1..=8).contains(&d)));
        let v = gen_vec(&mut rng, 16);
        assert!(v.iter().all(|x| (-1.0..=1.0).contains(x)));
    }
}
