//! PCG-XSH-RR 64/32 pseudo-random number generator.
//!
//! Deterministic, seedable and fast; used everywhere randomness is needed
//! (weight init, random-fraction sparsifiers, synthetic datasets) so that
//! every experiment in EXPERIMENTS.md is exactly reproducible.

/// A PCG-XSH-RR 64/32 generator (O'Neill, 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 bits of mantissa.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Pareto-distributed sample with scale 1 and shape `alpha > 0`, via
    /// inverse-CDF transform `(1 - u)^(-1/alpha)`. Heavy-tailed: the mean
    /// is `alpha / (alpha - 1)` for `alpha > 1` and infinite otherwise —
    /// used for realistic (fat-tailed) request-length mixes in the serving
    /// benches.
    pub fn pareto(&mut self, alpha: f64) -> f64 {
        debug_assert!(alpha > 0.0);
        let u = (1.0 - self.next_f32() as f64).max(1e-12); // in (0, 1]
        u.powf(-1.0 / alpha)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_reasonable() {
        let mut rng = Pcg64::seeded(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn pareto_mean_and_tail() {
        let mut rng = Pcg64::seeded(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.pareto(3.0)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0), "support starts at the scale");
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.5).abs() < 0.05, "mean {mean}"); // alpha/(alpha-1)
        // Heavier shape -> fatter tail: P(X > 10) is 10^-1.1 vs 10^-3.
        let mut rng = Pcg64::seeded(13);
        let heavy = (0..n).filter(|_| rng.pareto(1.1) > 10.0).count();
        let light = xs.iter().filter(|&&x| x > 10.0).count();
        assert!(heavy > 10 * light.max(1), "heavy {heavy} vs light {light}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(9);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
