//! Sync-primitive shim: `std::sync` / `std::thread` in normal builds,
//! the in-tree [`crate::util::loom`] model-checked types under
//! `--features loom`.
//!
//! Code ported to this shim (`util/threadpool.rs`, `util/channel.rs`,
//! `coordinator/concurrent.rs`, `dist/collective.rs`,
//! `coordinator/shard.rs`) imports `Arc`, `Mutex`, `Condvar`,
//! `atomic::*` and `thread::*` from here instead of `std` directly — the
//! `xtask lint` invariant `std-sync-in-ported-file` enforces it. In
//! a default build every re-export below is *exactly* the `std` item
//! (zero cost, no wrappers); with the `loom` feature the same names
//! resolve to model-aware types that delegate to `std` outside a
//! `loom::model(...)` run, so the full test suite still passes under
//! `cargo test --features loom`.

#[cfg(not(feature = "loom"))]
pub use std::sync::atomic;
#[cfg(not(feature = "loom"))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(not(feature = "loom"))]
pub use std::thread;

#[cfg(feature = "loom")]
pub use crate::util::loom::sync::atomic;
#[cfg(feature = "loom")]
pub use crate::util::loom::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(feature = "loom")]
pub use crate::util::loom::thread;

// `OnceLock` is only used for lazily initialized globals (the global
// thread pool); model executions never construct one, so the `std` type
// serves both configurations.
pub use std::sync::OnceLock;
