//! A small work-stealing-free scoped thread pool + a persistent worker pool.
//!
//! `rayon` is not available in the offline vendor set, so this provides the
//! primitives the kernels, the DDP simulator and the serving front-end need:
//!
//! * [`ThreadPool::scope_chunks`] — split an index range into contiguous
//!   chunks and run a closure per chunk on worker threads (used by the GEMM
//!   kernels to parallelize over row panels).
//! * [`parallel_for`] — one-shot convenience over a global pool, capped by
//!   the number of registered concurrent kernel users (engine replicas) so
//!   R replicas don't oversubscribe the machine by ~R x cores.
//! * [`WorkerPool`] — named, persistent worker threads consuming boxed jobs
//!   from a [`crate::util::channel`] queue (the serving subsystem runs its
//!   batcher and engine replicas on one of these).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::channel;

/// Number of concurrently-registered kernel users (see
/// [`register_kernel_users`]). 0 means "no serving layer active": kernels
/// get the whole pool.
static ACTIVE_KERNEL_USERS: AtomicUsize = AtomicUsize::new(0);

/// RAII registration of `n` concurrent kernel users. While the guard lives,
/// [`parallel_for`] divides the global pool among all registered users, so
/// e.g. 4 engine replicas on an 8-core host each get 2 kernel threads
/// instead of each GEMM trying to fan out over all 8 cores at once (which
/// oversubscribes by ~replicas x cores and thrashes). Dropping the guard
/// returns its share to the pool. Guards compose: two concurrent servers
/// with 2 replicas each register 4 users total.
#[derive(Debug)]
pub struct KernelUsersGuard {
    n: usize,
}

/// Register `n` concurrent kernel users (one per engine replica, typically).
pub fn register_kernel_users(n: usize) -> KernelUsersGuard {
    ACTIVE_KERNEL_USERS.fetch_add(n, Ordering::SeqCst);
    KernelUsersGuard { n }
}

/// Currently registered kernel users.
pub fn active_kernel_users() -> usize {
    ACTIVE_KERNEL_USERS.load(Ordering::SeqCst)
}

impl Drop for KernelUsersGuard {
    fn drop(&mut self) {
        ACTIVE_KERNEL_USERS.fetch_sub(self.n, Ordering::SeqCst);
    }
}

/// A persistent pool of worker threads executing closures.
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// Create a pool advertising `workers` workers. Threads are spawned per
    /// `scope_chunks` call (scoped threads), which keeps the implementation
    /// free of `'static` bounds while still amortizing well for the
    /// millisecond-scale tasks the kernels submit.
    pub fn new(workers: usize) -> Self {
        ThreadPool { workers: workers.max(1) }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(chunk_start, chunk_end)` over `[0, n)` split into contiguous
    /// chunks, one logical task per worker, self-balancing via an atomic
    /// cursor with step `grain`.
    pub fn scope_chunks<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        self.scope_chunks_with(n, grain, self.workers, f)
    }

    /// [`ThreadPool::scope_chunks`] with an explicit worker cap for this
    /// call. `max_workers <= 1` runs inline on the caller with no thread
    /// spawns at all — the fast path for capped replicas.
    pub fn scope_chunks_with<F>(&self, n: usize, grain: usize, max_workers: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        let nworkers = self.workers.min(max_workers.max(1)).min(n.div_ceil(grain));
        if nworkers <= 1 {
            f(0, n);
            return;
        }
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..nworkers {
                s.spawn(|| loop {
                    let start = cursor.fetch_add(grain, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + grain).min(n);
                    f(start, end);
                });
            }
        });
    }

    /// Map `f` over `0..n`, collecting results in index order.
    pub fn map<T: Send, F>(&self, n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize) -> T + Sync,
    {
        let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.scope_chunks(n, 1, |start, end| {
            for i in start..end {
                *results[i].lock().unwrap() = Some(f(i));
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker failed to produce value"))
            .collect()
    }
}

/// A raw mutable pointer wrapper that is `Sync`, for kernels whose threads
/// provably write disjoint regions. The `get()` accessor forces closures to
/// capture the whole wrapper (not the raw-pointer field) by reference.
pub struct SyncPtr<T>(pub *mut T);

unsafe impl<T> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    /// New wrapper over a raw pointer.
    pub fn new(p: *mut T) -> Self {
        SyncPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(&self) -> *mut T {
        self.0
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Named, persistent worker threads executing boxed jobs in submission
/// order. Unlike [`ThreadPool::scope_chunks`] (scoped, per-call threads for
/// data parallelism), a `WorkerPool` owns long-lived threads for
/// long-running tasks — the serving subsystem runs its batcher and each
/// engine replica as one job. Dropping (or [`WorkerPool::join`]ing) the
/// pool closes the queue and joins every worker.
pub struct WorkerPool {
    tx: Option<channel::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads named `{prefix}-{i}`.
    pub fn named(prefix: &str, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::bounded::<Job>(workers * 2);
        let handles = (0..workers)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        WorkerPool { tx: Some(tx), handles }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job; blocks while the job queue is full. Jobs submitted
    /// after the pool began shutting down are dropped.
    pub fn execute(&self, f: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Box::new(f));
        }
    }

    /// Close the queue and wait for all in-flight jobs to finish.
    pub fn join(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.tx.take(); // closes the channel; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The global pool, sized to available parallelism.
pub fn global() -> &'static Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        Arc::new(ThreadPool::new(n))
    })
}

/// Run `f(start, end)` over `[0, n)` chunks on the global pool. When kernel
/// users are registered (engine replicas serving concurrently), each call is
/// capped to its fair share `cores / users` of the pool so replicas compose
/// with kernel parallelism instead of multiplying against it.
pub fn parallel_for<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let pool = global();
    let users = active_kernel_users().max(1);
    let cap = (pool.workers() / users).max(1);
    pool.scope_chunks_with(n, grain, cap, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.scope_chunks(1000, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn sums_match_serial() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.scope_chunks(10_000, 128, |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(local, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), (0..10_000u64).sum());
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(4);
        pool.scope_chunks(0, 16, |_, _| panic!("must not run"));
    }

    #[test]
    fn worker_pool_runs_all_jobs_then_joins() {
        let pool = WorkerPool::named("tp-test", 3);
        assert_eq!(pool.workers(), 3);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..20 {
            let c = count.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(count.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn single_worker_runs_inline() {
        let pool = ThreadPool::new(1);
        let count = AtomicUsize::new(0);
        pool.scope_chunks(10, 100, |s, e| {
            count.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn capped_scope_chunks_still_covers_range() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.scope_chunks_with(1000, 10, 2, |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(local, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), (0..1000u64).sum());
    }

    #[test]
    fn kernel_users_guard_caps_parallel_for_and_releases() {
        // One test (not two) so the global ACTIVE_KERNEL_USERS assertions
        // can't race against a sibling test's guard in the parallel harness;
        // this is the only lib test touching the counter.
        let before = active_kernel_users();
        let g = register_kernel_users(3);
        assert!(active_kernel_users() >= before + 3);
        drop(g);
        assert_eq!(active_kernel_users(), before);

        // A user count far above any core count forces the inline path;
        // coverage must be unchanged.
        let _g = register_kernel_users(1024);
        let hits: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(500, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        drop(_g);
        assert_eq!(active_kernel_users(), before);
    }
}
