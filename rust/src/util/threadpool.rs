//! Persistent work-stealing thread pool + a long-running-job worker pool.
//!
//! `rayon` is not available in the offline vendor set, so this provides the
//! primitives the kernels, the DDP simulator and the serving front-end need:
//!
//! * [`ThreadPool`] — **persistent** workers parked on per-worker deques.
//!   [`ThreadPool::scope_chunks`] injects one *ticket* per budgeted worker
//!   onto the deques (idle workers steal tickets from the back of other
//!   deques); every ticket holder — the calling thread included — loops the
//!   scope's shared cursor, claiming one grain-sized chunk per iteration,
//!   so load balances at grain granularity while the per-scope worker
//!   budget stays a hard bound. The caller executes chunks itself while it
//!   waits, so nested scopes (a kernel called from inside a parallelized
//!   block) cannot deadlock. No threads are spawned per call — workers are
//!   spawned once at pool construction and live until drop (see
//!   [`total_spawns`]).
//! * [`parallel_for`] — one-shot convenience over the global pool, capped
//!   by the per-scope worker budget derived from the number of registered
//!   concurrent kernel users (engine replicas), so R replicas don't
//!   oversubscribe the machine by ~R x cores.
//! * [`WorkerPool`] — named, persistent worker threads consuming boxed jobs
//!   from a [`crate::util::channel`] queue (the serving subsystem runs its
//!   batcher and engine replicas on one of these).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use super::channel;
use super::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use super::sync::{thread, Arc, Condvar, Mutex, OnceLock};

/// Number of concurrently-registered kernel users (see
/// [`register_kernel_users`]). 0 means "no serving layer active": kernels
/// get the whole pool.
static ACTIVE_KERNEL_USERS: AtomicUsize = AtomicUsize::new(0);

/// Threads spawned by this module over the process lifetime ([`ThreadPool`]
/// workers + [`WorkerPool`] workers). Benches assert this stays flat across
/// steady-state requests: all kernel parallelism must come from the
/// persistent pool, never from per-call spawns.
static TOTAL_SPAWNS: AtomicUsize = AtomicUsize::new(0);

/// Global override on the per-scope worker budget (0 = none). Benches use
/// this to sweep kernel parallelism from 1 to `cores` on one process.
static WORKER_CAP: AtomicUsize = AtomicUsize::new(0);

/// Element count below which data-parallel tensor helpers (row-wise
/// elementwise kernels, transposes) should stay on the calling thread:
/// tiny tensors don't amortize even a spawn-free scope, and the S x S
/// attention intermediates processed from *inside* per-(batch, head) pool
/// tasks must not open nested scopes. Shared so the sites can't silently
/// diverge.
pub const SERIAL_THRESHOLD: usize = 32 * 1024;

/// Threads spawned by this module so far (monotonic).
pub fn total_spawns() -> usize {
    TOTAL_SPAWNS.load(Ordering::SeqCst)
}

/// Cap every subsequent [`parallel_for`] at `cap` workers (`None` removes
/// the cap). Composes with the kernel-users budget: the effective budget is
/// the minimum of the two.
pub fn set_worker_cap(cap: Option<usize>) {
    WORKER_CAP.store(cap.map_or(0, |c| c.max(1)), Ordering::SeqCst);
}

/// RAII registration of `n` concurrent kernel users. While the guard lives,
/// [`parallel_for`] divides the global pool among all registered users, so
/// e.g. 4 engine replicas on an 8-core host each get a per-scope budget of
/// 2 workers instead of each GEMM trying to fan out over all 8 cores at
/// once (which oversubscribes by ~replicas x cores and thrashes). The cap
/// is a *budget on units injected per scope*, not a spawn count: a budget
/// of 1 runs inline on the caller with no pool interaction at all.
/// Dropping the guard returns its share to the pool. Guards compose: two
/// concurrent servers with 2 replicas each register 4 users total.
#[derive(Debug)]
pub struct KernelUsersGuard {
    n: usize,
}

/// Register `n` concurrent kernel users (one per engine replica, typically).
pub fn register_kernel_users(n: usize) -> KernelUsersGuard {
    ACTIVE_KERNEL_USERS.fetch_add(n, Ordering::SeqCst);
    KernelUsersGuard { n }
}

/// Currently registered kernel users.
pub fn active_kernel_users() -> usize {
    ACTIVE_KERNEL_USERS.load(Ordering::SeqCst)
}

/// The per-scope worker budget [`parallel_for`] runs under right now:
/// `pool workers / registered users`, clamped to at least 1 and further
/// capped by [`set_worker_cap`].
pub fn kernel_worker_budget() -> usize {
    let users = active_kernel_users().max(1);
    let mut budget = (global().workers() / users).max(1);
    let cap = WORKER_CAP.load(Ordering::SeqCst);
    if cap != 0 {
        budget = budget.min(cap);
    }
    budget
}

impl Drop for KernelUsersGuard {
    fn drop(&mut self) {
        ACTIVE_KERNEL_USERS.fetch_sub(self.n, Ordering::SeqCst);
    }
}

/// Lifetime-erased pointer to a scope's chunk closure. Only invoked while
/// the owning [`ThreadPool::scope_chunks_with`] call is blocked in
/// `wait_done`, which guarantees the closure is still alive.
type RawTask = *const (dyn Fn(usize, usize) + Sync);

/// One in-flight scope: the erased closure plus completion bookkeeping.
///
/// Exactly `w` (the scope's worker budget) tickets reference a job: one
/// held by the scope owner, `w - 1` queued on worker deques. Each ticket
/// holder loops the shared `cursor`, claiming one grain-sized chunk per
/// iteration — so at most `w` threads ever execute the scope (the budget
/// is a hard bound, not a hint) while load still balances at grain
/// granularity for the cost of one relaxed `fetch_add` per chunk.
struct Job {
    func: RawTask,
    grain: usize,
    n: usize,
    /// Next index to claim (grain stride).
    cursor: AtomicUsize,
    /// Indices whose chunks have finished executing; 0 = scope complete.
    remaining: AtomicUsize,
    /// Pairs with `done` so the final decrement's wakeup can't be lost.
    done_lock: Mutex<()>,
    done: Condvar,
    panicked: AtomicBool,
    /// First caught panic payload, re-raised by the scope owner so the
    /// original message (assertion text, kernel shapes) survives the pool.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: `func` is only dereferenced while the scope owner keeps the
// closure alive (it blocks until `remaining` hits 0); all other fields are
// Send + Sync already.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// Erase the lifetime of a scope closure reference so it can ride in an
/// [`Arc<Job>`] on worker deques.
///
/// # Safety
///
/// The returned pointer must not be dereferenced after the scope that owns
/// the closure returns; `scope_chunks_with` guarantees this by blocking
/// until every chunk has finished executing.
#[allow(clippy::useless_transmute, clippy::transmute_ptr_to_ptr)]
unsafe fn erase_task_lifetime(f: &(dyn Fn(usize, usize) + Sync)) -> RawTask {
    // SAFETY: deferred to the caller's contract above — the pointer only
    // outlives the borrow, never the referent.
    unsafe { std::mem::transmute::<&(dyn Fn(usize, usize) + Sync), RawTask>(f) }
}

/// A ticket for one job, queued on a worker deque: whoever pops it joins
/// the job's cursor loop until the range is exhausted. Tickets left over
/// after a job completes are popped and dropped without running anything.
struct Unit {
    job: Arc<Job>,
}

/// State shared between the pool handle and its workers.
struct PoolShared {
    /// Per-worker deques: owners pop from the front, thieves from the back.
    queues: Vec<Mutex<VecDeque<Unit>>>,
    /// Wake epoch: bumped on every injection so parked workers never miss
    /// work pushed between their queue scan and their wait.
    sleep: Mutex<u64>,
    wake: Condvar,
    shutdown: AtomicBool,
    /// Round-robin injection cursor.
    rr: AtomicUsize,
}

impl PoolShared {
    fn push_unit(&self, q: usize, unit: Unit) {
        self.queues[q].lock().unwrap().push_back(unit);
    }

    /// Bump the wake epoch and wake parked workers.
    fn bump_and_wake(&self) {
        let mut epoch = self.sleep.lock().unwrap();
        *epoch += 1;
        drop(epoch);
        self.wake.notify_all();
    }

    /// Pop any unit: own deque front first, then steal from other backs.
    fn try_pop(&self, home: usize) -> Option<Unit> {
        if let Some(u) = self.queues[home].lock().unwrap().pop_front() {
            return Some(u);
        }
        let nq = self.queues.len();
        for off in 1..nq {
            let q = (home + off) % nq;
            if let Some(u) = self.queues[q].lock().unwrap().pop_back() {
                return Some(u);
            }
        }
        None
    }

    /// Join `job`'s cursor loop: claim and execute one grain-sized chunk
    /// per iteration until the range is exhausted. This is the whole worker
    /// share of a scope — one relaxed `fetch_add` and one `fetch_sub` per
    /// chunk, no locks.
    fn run_ticket(&self, job: &Arc<Job>) {
        loop {
            let start = job.cursor.fetch_add(job.grain, Ordering::Relaxed);
            if start >= job.n {
                return;
            }
            let end = (start + job.grain).min(job.n);
            if !job.panicked.load(Ordering::SeqCst) {
                // SAFETY: the scope owner is blocked until `remaining`
                // reaches 0, so the closure behind `func` is alive here.
                let call = || unsafe { (&*job.func)(start, end) };
                if let Err(payload) = catch_unwind(AssertUnwindSafe(call)) {
                    // Poison the job (remaining chunks are skipped, the
                    // scope owner re-raises the original payload) but keep
                    // this worker alive.
                    let mut slot = job.panic_payload.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    drop(slot);
                    job.panicked.store(true, Ordering::SeqCst);
                }
            }
            if job.remaining.fetch_sub(end - start, Ordering::AcqRel) == end - start {
                // Final chunk: wake the scope owner. Taking the (empty)
                // critical section first pairs with the owner's locked
                // check-then-wait, so the wakeup cannot be lost.
                drop(job.done_lock.lock().unwrap());
                job.done.notify_all();
            }
        }
    }

    /// Block until every index of `job` has finished executing.
    fn wait_done(&self, job: &Arc<Job>) {
        if job.remaining.load(Ordering::Acquire) == 0 {
            return;
        }
        let mut guard = job.done_lock.lock().unwrap();
        while job.remaining.load(Ordering::Acquire) != 0 {
            // In-flight chunks run on workers holding tickets; the final
            // decrement notifies `done`. The timeout is a lost-wakeup
            // backstop only.
            let (g, _) = job.done.wait_timeout(guard, Duration::from_millis(1)).unwrap();
            guard = g;
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>, idx: usize) {
    loop {
        if let Some(unit) = shared.try_pop(idx) {
            shared.run_ticket(&unit.job);
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Nothing queued: read the epoch, re-scan (an injection between the
        // first scan and the epoch read would otherwise be missed while we
        // sleep), then park until the epoch moves. The epoch lock is only
        // touched on this idle edge, never in the busy pop/execute loop.
        let seen = *shared.sleep.lock().unwrap();
        if let Some(unit) = shared.try_pop(idx) {
            shared.run_ticket(&unit.job);
            continue;
        }
        let guard = shared.sleep.lock().unwrap();
        if *guard == seen && !shared.shutdown.load(Ordering::SeqCst) {
            // Park until the epoch moves; the timeout bounds any race
            // between our queue scan and a concurrent injection.
            let (guard, _) = shared.wake.wait_timeout(guard, Duration::from_millis(50)).unwrap();
            drop(guard);
        }
    }
}

/// A persistent pool of worker threads executing scoped data-parallel work.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: usize,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `workers` persistent worker threads (spawned here,
    /// once; `scope_chunks` never spawns). A 1-worker pool spawns no threads
    /// at all — every scope runs inline on the caller.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let spawned = if workers >= 2 { workers } else { 0 };
        let shared = Arc::new(PoolShared {
            queues: (0..spawned.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Mutex::new(0),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            rr: AtomicUsize::new(0),
        });
        let handles = (0..spawned)
            .map(|i| {
                TOTAL_SPAWNS.fetch_add(1, Ordering::SeqCst);
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("sten-pool-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers, handles }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Threads this pool has spawned (constant after construction — the
    /// steady-state invariant the benches assert).
    pub fn spawn_count(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(chunk_start, chunk_end)` over `[0, n)` split into contiguous
    /// grain-sized chunks, cooperatively balanced across the pool workers
    /// and the calling thread.
    pub fn scope_chunks<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        self.scope_chunks_with(n, grain, self.workers, f)
    }

    /// [`ThreadPool::scope_chunks`] with an explicit worker budget for this
    /// scope. `max_workers <= 1` runs inline on the caller with no pool
    /// interaction at all — the fast path for capped replicas.
    pub fn scope_chunks_with<F>(&self, n: usize, grain: usize, max_workers: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        let w = self.workers.min(max_workers.max(1)).min(n.div_ceil(grain));
        if w <= 1 || self.handles.is_empty() {
            f(0, n);
            return;
        }
        let func_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        // SAFETY: `wait_done` below returns only once `remaining` hits 0,
        // i.e. after the last chunk has finished executing; no worker
        // dereferences `func` afterwards (stale tickets see the exhausted
        // cursor before touching it).
        let func: RawTask = unsafe { erase_task_lifetime(func_ref) };
        let job = Arc::new(Job {
            func,
            grain,
            n,
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n),
            done_lock: Mutex::new(()),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
            panic_payload: Mutex::new(None),
        });
        // w - 1 stealable tickets round-robin across deques; the calling
        // thread is the w-th participant. The budget is exact: no thread
        // beyond these w can ever join the scope.
        let nq = self.shared.queues.len();
        let home = self.shared.rr.fetch_add(1, Ordering::Relaxed) % nq;
        for t in 0..w - 1 {
            self.shared.push_unit((home + t) % nq, Unit { job: Arc::clone(&job) });
        }
        self.shared.bump_and_wake();
        self.shared.run_ticket(&job);
        self.shared.wait_done(&job);
        if job.panicked.load(Ordering::SeqCst) {
            // Re-raise the first caught payload so the original panic
            // message survives the pool (matching scoped-thread behavior).
            match job.panic_payload.lock().unwrap().take() {
                Some(payload) => std::panic::resume_unwind(payload),
                None => panic!("threadpool: a scoped task panicked"),
            }
        }
    }

    /// Map `f` over `0..n`, collecting results in index order.
    pub fn map<T: Send, F>(&self, n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize) -> T + Sync,
    {
        let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.scope_chunks(n, 1, |start, end| {
            for i in start..end {
                *results[i].lock().unwrap() = Some(f(i));
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker failed to produce value"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.bump_and_wake();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A raw mutable pointer wrapper that is `Sync`, for kernels whose threads
/// provably write disjoint regions. The `get()` accessor forces closures to
/// capture the whole wrapper (not the raw-pointer field) by reference.
pub struct SyncPtr<T>(pub *mut T);

// SAFETY: sharing the *pointer value* across threads is always sound; it
// is each dereference site that must argue disjointness (every kernel
// using `SyncPtr` carries that SAFETY comment on its unsafe block).
unsafe impl<T> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    /// New wrapper over a raw pointer.
    pub fn new(p: *mut T) -> Self {
        SyncPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(&self) -> *mut T {
        self.0
    }
}

type BoxedJob = Box<dyn FnOnce() + Send + 'static>;

/// Named, persistent worker threads executing boxed jobs in submission
/// order. Unlike [`ThreadPool::scope_chunks`] (grain-sized data-parallel
/// chunks), a `WorkerPool` owns long-lived threads for long-running tasks —
/// the serving subsystem runs its batcher and each engine replica as one
/// job. Dropping (or [`WorkerPool::join`]ing) the pool closes the queue and
/// joins every worker.
pub struct WorkerPool {
    tx: Option<channel::Sender<BoxedJob>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads named `{prefix}-{i}`.
    pub fn named(prefix: &str, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::bounded::<BoxedJob>(workers * 2);
        let handles = (0..workers)
            .map(|i| {
                TOTAL_SPAWNS.fetch_add(1, Ordering::SeqCst);
                let rx = rx.clone();
                thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        WorkerPool { tx: Some(tx), handles }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job; blocks while the job queue is full. Jobs submitted
    /// after the pool began shutting down are dropped.
    pub fn execute(&self, f: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Box::new(f));
        }
    }

    /// Close the queue and wait for all in-flight jobs to finish.
    pub fn join(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.tx.take(); // closes the channel; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The global pool, sized to available parallelism. Constructed (and its
/// workers spawned) exactly once, on first use.
pub fn global() -> &'static Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        Arc::new(ThreadPool::new(n))
    })
}

/// Run `f(start, end)` over `[0, n)` chunks on the global pool under the
/// current per-scope worker budget (see [`kernel_worker_budget`]): when
/// kernel users are registered (engine replicas serving concurrently), each
/// scope is capped to its fair share `cores / users` of the pool so
/// replicas compose with kernel parallelism instead of multiplying against
/// it. No threads are ever spawned here — work runs on the persistent pool
/// workers (and inline on the caller).
pub fn parallel_for<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    global().scope_chunks_with(n, grain, kernel_worker_budget(), f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.scope_chunks(1000, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn sums_match_serial() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.scope_chunks(10_000, 128, |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(local, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), (0..10_000u64).sum());
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(4);
        pool.scope_chunks(0, 16, |_, _| panic!("must not run"));
    }

    #[test]
    fn worker_pool_runs_all_jobs_then_joins() {
        let pool = WorkerPool::named("tp-test", 3);
        assert_eq!(pool.workers(), 3);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..20 {
            let c = count.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(count.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn single_worker_runs_inline_and_spawns_nothing() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.spawn_count(), 0);
        let count = AtomicUsize::new(0);
        pool.scope_chunks(10, 100, |s, e| {
            count.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn capped_scope_chunks_still_covers_range() {
        let pool = ThreadPool::new(8);
        let total = AtomicU64::new(0);
        pool.scope_chunks_with(1000, 10, 2, |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            total.fetch_add(local, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), (0..1000u64).sum());
    }

    #[test]
    fn scopes_are_spawn_free_in_steady_state() {
        let pool = ThreadPool::new(4);
        let spawned = pool.spawn_count();
        assert_eq!(spawned, 4);
        for round in 0..50 {
            let total = AtomicU64::new(0);
            pool.scope_chunks(997, 13, |s, e| {
                let local: u64 = (s..e).map(|i| i as u64).sum();
                total.fetch_add(local, Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), (0..997u64).sum(), "round {round}");
        }
        // Persistent workers only: repeated scopes never spawn.
        assert_eq!(pool.spawn_count(), spawned);
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_chunks(100, 1, |s, _| {
                if s == 13 {
                    panic!("boom");
                }
            });
        }));
        // The original payload must survive the pool (debuggability).
        let payload = result.expect_err("scope must propagate the task panic");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // The poisoned task was isolated: workers are alive and later
        // scopes on the same pool run to completion.
        let hits: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        pool.scope_chunks(500, 3, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn nested_scopes_complete_without_deadlock() {
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        pool.scope_chunks(8, 1, |o0, o1| {
            for _outer in o0..o1 {
                // Nested scope on the same pool, executed from a worker (or
                // the helping caller): waiters help with their own scope's
                // units, so this must not deadlock.
                let inner = AtomicU64::new(0);
                pool.scope_chunks(256, 8, |s, e| {
                    let local: u64 = (s..e).map(|i| i as u64).sum();
                    inner.fetch_add(local, Ordering::SeqCst);
                });
                assert_eq!(inner.load(Ordering::SeqCst), (0..256u64).sum());
                total.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn clean_shutdown_on_drop() {
        let before = total_spawns();
        let pool = ThreadPool::new(3);
        assert!(total_spawns() >= before + 3);
        let count = AtomicUsize::new(0);
        pool.scope_chunks(64, 4, |s, e| {
            count.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 64);
        // Drop must join every worker promptly (a deadlocked parked worker
        // would hang the test harness here and trip the CI time ceiling).
        drop(pool);
    }

    #[test]
    fn kernel_users_guard_caps_parallel_for_and_releases() {
        // One test (not several) so the global ACTIVE_KERNEL_USERS and
        // WORKER_CAP assertions can't race against a sibling test's guard
        // in the parallel harness; this is the only lib test touching them.
        let workers = global().workers();
        let before = active_kernel_users();
        let g = register_kernel_users(3);
        assert!(active_kernel_users() >= before + 3);
        drop(g);
        assert_eq!(active_kernel_users(), before);

        // Budget arithmetic: users divide the pool, floor 1, cap composes.
        if before == 0 {
            assert_eq!(kernel_worker_budget(), workers);
            let g2 = register_kernel_users(2);
            assert_eq!(kernel_worker_budget(), (workers / 2).max(1));
            drop(g2);
            let g1024 = register_kernel_users(1024);
            assert_eq!(kernel_worker_budget(), 1);
            drop(g1024);
            set_worker_cap(Some(1));
            assert_eq!(kernel_worker_budget(), 1);
            set_worker_cap(None);
            assert_eq!(kernel_worker_budget(), workers);
        }

        // A user count far above any core count forces the inline path;
        // coverage must be unchanged.
        let _g = register_kernel_users(1024);
        let hits: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(500, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        drop(_g);
        assert_eq!(active_kernel_users(), before);
    }
}
