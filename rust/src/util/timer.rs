//! Timing helpers: a stopwatch and a named time breakdown used by the
//! coordinator to split end-to-end latency into "STen (dispatch) time" vs
//! "runtime (kernel) time", the breakdown reported in Fig. 11 of the paper.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// A simple restartable stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time since the (re)start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds since the (re)start.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap duration.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.elapsed();
        self.start = Instant::now();
        d
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates named durations, e.g. `dispatch`, `kernel`, `convert`,
/// `runtime` — the per-component latency breakdown of Fig. 11.
#[derive(Debug, Default, Clone)]
pub struct TimeBreakdown {
    buckets: HashMap<&'static str, Duration>,
}

impl TimeBreakdown {
    /// New empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to bucket `name`.
    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.buckets.entry(name).or_default() += d;
    }

    /// Time `f` and charge its duration to `name`.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    /// Total across buckets.
    pub fn total(&self) -> Duration {
        self.buckets.values().sum()
    }

    /// Seconds in bucket `name` (0 if absent).
    pub fn secs(&self, name: &str) -> f64 {
        self.buckets.get(name).copied().unwrap_or_default().as_secs_f64()
    }

    /// Merge another breakdown into this one.
    pub fn merge(&mut self, other: &TimeBreakdown) {
        for (k, v) in &other.buckets {
            *self.buckets.entry(k).or_default() += *v;
        }
    }

    /// Buckets sorted by descending time, as `(name, seconds)`.
    pub fn sorted(&self) -> Vec<(&'static str, f64)> {
        let mut v: Vec<_> = self.buckets.iter().map(|(k, d)| (*k, d.as_secs_f64())).collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = TimeBreakdown::new();
        b.add("dispatch", Duration::from_millis(2));
        b.add("dispatch", Duration::from_millis(3));
        b.add("kernel", Duration::from_millis(10));
        assert!((b.secs("dispatch") - 0.005).abs() < 1e-9);
        assert!((b.total().as_secs_f64() - 0.015).abs() < 1e-9);
    }

    #[test]
    fn time_charges_bucket() {
        let mut b = TimeBreakdown::new();
        let x = b.time("work", || 21 * 2);
        assert_eq!(x, 42);
        assert!(b.secs("work") >= 0.0);
    }

    #[test]
    fn sorted_descending() {
        let mut b = TimeBreakdown::new();
        b.add("small", Duration::from_micros(1));
        b.add("big", Duration::from_millis(1));
        let order: Vec<_> = b.sorted().into_iter().map(|(k, _)| k).collect();
        assert_eq!(order, vec!["big", "small"]);
    }

    #[test]
    fn merge_sums() {
        let mut a = TimeBreakdown::new();
        a.add("x", Duration::from_millis(1));
        let mut b = TimeBreakdown::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(4));
        a.merge(&b);
        assert!((a.secs("x") - 0.003).abs() < 1e-9);
        assert!((a.secs("y") - 0.004).abs() < 1e-9);
    }
}
