//! Autotuner determinism contract: the same manifest of weights tuned twice
//! from scratch must produce identical decisions AND byte-identical cache
//! files, and the cache must invalidate (by key inequality) whenever a shape,
//! sparsity level, n:m:g config, or compute backend changes.

use sten::dispatch::Dispatcher;
use sten::formats::{Layout, NmgTensor};
use sten::kernels::backend::Backend;
use sten::sparsify::{ScalarFraction, Sparsifier};
use sten::tensor::DenseTensor;
use sten::tune::{tune_key, Autotuner, Decision, TuneCache, TunePolicy, WeightStats};
use sten::util::rng::Pcg64;

/// A small "model manifest": weights of varied shape and sparsity structure,
/// each paired with the activation width and n:m:g config it is tuned for.
fn manifest() -> Vec<(DenseTensor, usize, Option<(usize, usize, usize)>)> {
    let mut rng = Pcg64::seeded(2024);
    let mut out = Vec::new();
    // Structured n:m:g-pruned layers (the engine's FFN case).
    let cfgs: [(usize, usize, (usize, usize, usize)); 3] =
        [(16, 32, (2, 4, 2)), (24, 48, (1, 4, 2)), (16, 32, (2, 8, 2))];
    for &(rows, cols, nmg) in &cfgs {
        let d = DenseTensor::randn(&[rows, cols], &mut rng);
        let pruned = NmgTensor::from_dense(&d, nmg.0, nmg.1, nmg.2).to_dense();
        out.push((pruned, 8, Some(nmg)));
    }
    // Unstructured-pruned and fully dense layers (no n:m:g config).
    let d = DenseTensor::randn(&[20, 40], &mut rng);
    out.push((ScalarFraction { fraction: 0.9 }.prune(&d), 8, None));
    out.push((DenseTensor::randn(&[12, 24], &mut rng), 8, None));
    out
}

fn tune_all(d: &Dispatcher, tuner: &mut Autotuner) -> Vec<Decision> {
    manifest()
        .iter()
        .map(|(w, ncols, nmg)| tuner.choose(d, w, *ncols, *nmg).expect("choose"))
        .collect()
}

#[test]
fn same_manifest_tunes_to_identical_decisions_and_byte_identical_cache() {
    let d = Dispatcher::with_builtins();
    let dir = std::env::temp_dir().join("sten_autotune_determinism_test");
    std::fs::create_dir_all(&dir).unwrap();

    let mut run_a = Autotuner::new(TunePolicy::CostModel);
    let mut run_b = Autotuner::new(TunePolicy::CostModel);
    let decs_a = tune_all(&d, &mut run_a);
    let decs_b = tune_all(&d, &mut run_b);
    assert_eq!(decs_a, decs_b, "two fresh runs over the same manifest must agree");
    assert!(run_a.misses >= 1 && run_a.hits == 0, "fresh run answers nothing from cache");

    let path_a = dir.join("cache_a.json");
    let path_b = dir.join("cache_b.json");
    run_a.cache.save(&path_a).unwrap();
    run_b.cache.save(&path_b).unwrap();
    let bytes_a = std::fs::read(&path_a).unwrap();
    let bytes_b = std::fs::read(&path_b).unwrap();
    assert_eq!(bytes_a, bytes_b, "same decisions must serialize to byte-identical cache files");

    // A third run seeded from the saved cache replays every decision without
    // re-scoring, and re-saving changes nothing on disk.
    let warm = TuneCache::load(&path_a).unwrap();
    let mut replay = Autotuner::with_cache(TunePolicy::CostModel, warm);
    let decs_c = tune_all(&d, &mut replay);
    assert_eq!(decs_a, decs_c);
    assert_eq!(replay.misses, 0, "warm cache must answer every query");
    assert_eq!(replay.hits as usize, manifest().len());
    replay.cache.save(&path_a).unwrap();
    assert_eq!(std::fs::read(&path_a).unwrap(), bytes_a, "replay save must be a byte-level no-op");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shape_and_sparsity_changes_miss_the_cache() {
    let d = Dispatcher::with_builtins();
    let mut rng = Pcg64::seeded(77);
    let raw = DenseTensor::randn(&[16, 32], &mut rng);
    let base = NmgTensor::from_dense(&raw, 2, 4, 2).to_dense();
    let mut tuner = Autotuner::new(TunePolicy::CostModel);
    tuner.choose(&d, &base, 8, Some((2, 4, 2))).unwrap();
    assert_eq!((tuner.hits, tuner.misses), (0, 1));

    // Same weight again: pure cache hit.
    tuner.choose(&d, &base, 8, Some((2, 4, 2))).unwrap();
    assert_eq!((tuner.hits, tuner.misses), (1, 1));

    // Shape change (more rows), sparsity change (1:4 instead of 2:4), and
    // activation-width change each produce a distinct key -> re-tune.
    let tall = DenseTensor::randn(&[24, 32], &mut rng);
    let taller = NmgTensor::from_dense(&tall, 2, 4, 2).to_dense();
    tuner.choose(&d, &taller, 8, Some((2, 4, 2))).unwrap();
    let sparser = NmgTensor::from_dense(&base, 1, 4, 2).to_dense();
    tuner.choose(&d, &sparser, 8, Some((1, 4, 2))).unwrap();
    tuner.choose(&d, &base, 16, Some((2, 4, 2))).unwrap();
    assert_eq!((tuner.hits, tuner.misses), (1, 4));
    assert_eq!(tuner.cache.len(), 4, "each distinct (shape, sparsity, ncols) gets its own entry");
}

#[test]
fn backend_change_invalidates_the_cache_key() {
    // A decision tuned under one backend must never be replayed under the
    // other: the SIMD cost model ranks irregular formats differently. Key
    // inequality is the whole invalidation mechanism, so pin it directly
    // (pure key computation — no backend forcing, no cache I/O).
    let mut rng = Pcg64::seeded(78);
    let raw = DenseTensor::randn(&[16, 32], &mut rng);
    let w = NmgTensor::from_dense(&raw, 2, 4, 2).to_dense();
    let stats = WeightStats::measure(&w);
    let scalar_key = tune_key(&stats, 8, Some((2, 4, 2)), Backend::Scalar);
    let simd_key = tune_key(&stats, 8, Some((2, 4, 2)), Backend::Simd);
    assert_ne!(scalar_key, simd_key, "backend must be part of the cache key");
    assert!(scalar_key.ends_with(":bescalar"), "got {scalar_key}");
    assert!(simd_key.ends_with(":besimd"), "got {simd_key}");
    // Everything upstream of the backend suffix is identical: the backend
    // only extends the key, it does not perturb shape/sparsity fields.
    assert_eq!(
        scalar_key.rsplit_once(":be").unwrap().0,
        simd_key.rsplit_once(":be").unwrap().0
    );
}

#[test]
fn schema_bump_forces_a_full_retune_with_identical_outcome() {
    let d = Dispatcher::with_builtins();
    let dir = std::env::temp_dir().join("sten_autotune_schema_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.json");

    let mut first = Autotuner::new(TunePolicy::CostModel);
    let decs = tune_all(&d, &mut first);
    first.cache.save(&path).unwrap();

    // Corrupt the schema: the loader must drop every entry rather than trust
    // decisions produced under different cost-model units.
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replace("\"schema\":2", "\"schema\":999")).unwrap();
    let dropped = TuneCache::load(&path).unwrap();
    let mut second = Autotuner::with_cache(TunePolicy::CostModel, dropped);
    assert!(second.cache.is_empty(), "schema mismatch must drop the cache wholesale");
    let redecs = tune_all(&d, &mut second);
    assert_eq!(second.hits, 0, "dropped cache means every query re-scores");
    assert_eq!(decs, redecs, "re-tuning under the same policy reaches the same decisions");
    assert!(redecs.iter().any(|dec| dec.layout == Layout::Nmg), "pruned layers should pick n:m:g");

    std::fs::remove_dir_all(&dir).ok();
}
