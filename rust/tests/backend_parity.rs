//! Cross-backend golden-vector parity harness (the SIMD backend's gate).
//!
//! Every runtime artifact is checked on both backends against golden
//! vectors generated from the forced-scalar reference (see `sten::parity`),
//! and every SIMD kernel is checked directly against its scalar twin.
//! Backend forcing happens only in this integration binary (and its
//! siblings), never in the lib test binary: the `backend::force` guards
//! serialize through a process-global lock, so concurrently running tests
//! here cannot observe a half-switched backend.
//!
//! Tolerance contract per seam lives in `sten::parity::SEAMS`; the
//! bit-identical seams (embed artifact, softmax, bias_add) are asserted
//! with exact equality, everything else with the seam's allclose bounds.

use sten::formats::bcsr::BcsrTensor;
use sten::formats::nmg::NmgTensor;
use sten::kernels::backend::{self, Backend};
use sten::kernels::{bcsr_gemm, dense_gemm, elementwise, nmg_gemm, simd};
use sten::parity;
use sten::runtime::{ArtifactRuntime, Value};
use sten::tensor::DenseTensor;
use sten::util::rng::Pcg64;

fn runtime() -> ArtifactRuntime {
    ArtifactRuntime::open_default().expect("artifact runtime")
}

/// Generate every golden *before* any force guard is taken (golden
/// generation takes the guard internally and it is not reentrant).
fn ensure_all(rt: &ArtifactRuntime) -> Vec<String> {
    let names = parity::sweep_artifacts(rt);
    for n in &names {
        parity::ensure_golden(rt, n).unwrap_or_else(|e| panic!("golden for {n}: {e}"));
    }
    names
}

/// Run `f` with the given backend forced (guard held for the duration).
fn with_backend<T>(b: Backend, f: impl FnOnce() -> T) -> T {
    let _g = backend::force(b);
    f()
}

#[test]
fn scalar_backend_reproduces_every_golden() {
    let rt = runtime();
    let names = ensure_all(&rt);
    let _g = backend::force(Backend::Scalar);
    for n in &names {
        parity::verify_artifact(&rt, n).unwrap_or_else(|e| panic!("scalar parity: {e}"));
    }
}

#[test]
fn simd_backend_matches_goldens_within_seam_tolerances() {
    if !simd::have_avx2_fma() {
        eprintln!("skipping SIMD parity sweep: no AVX2+FMA on this host");
        return;
    }
    let rt = runtime();
    let names = ensure_all(&rt);
    let _g = backend::force(Backend::Simd);
    for n in &names {
        parity::verify_artifact(&rt, n).unwrap_or_else(|e| panic!("simd parity: {e}"));
    }
}

#[test]
fn scalar_reference_is_deterministic_bitwise() {
    // The golden generator's claim: same name -> same inputs -> same bytes.
    let rt = runtime();
    for n in parity::sweep_artifacts(&rt) {
        let i1 = parity::synth_inputs(&rt, &n).unwrap();
        let i2 = parity::synth_inputs(&rt, &n).unwrap();
        let (o1, o2) = with_backend(Backend::Scalar, || {
            (rt.call(&n, &i1).unwrap(), rt.call(&n, &i2).unwrap())
        });
        for (a, b) in o1.iter().zip(&o2) {
            match (a, b) {
                (Value::F32(x), Value::F32(y)) => assert_eq!(x.data(), y.data(), "{n}"),
                (Value::I32(_, x), Value::I32(_, y)) => assert_eq!(x, y, "{n}"),
                _ => panic!("{n}: output dtype mismatch between identical calls"),
            }
        }
    }
}

#[test]
fn bit_identical_seams_agree_exactly_across_backends() {
    if !simd::have_avx2_fma() {
        eprintln!("skipping bit-identity cross-backend check: no AVX2+FMA");
        return;
    }
    let rt = runtime();
    let names = ensure_all(&rt);
    for n in names.iter().filter(|n| parity::seam_for(n).bit_identical) {
        let path = parity::ensure_golden(&rt, n).unwrap();
        let (inputs, _) = parity::load_golden(&rt, n, &path).unwrap();
        let scalar = with_backend(Backend::Scalar, || rt.call(n, &inputs).unwrap());
        let vector = with_backend(Backend::Simd, || rt.call(n, &inputs).unwrap());
        for (i, (s, v)) in scalar.iter().zip(&vector).enumerate() {
            assert_eq!(
                s.as_f32().unwrap().data(),
                v.as_f32().unwrap().data(),
                "{n} output {i}: bit-identical seam diverged"
            );
        }
    }
}

#[test]
fn dense_gemm_parity_scalar_vs_simd() {
    if !simd::have_avx2_fma() {
        return;
    }
    let mut rng = Pcg64::seeded(901);
    // Full tiles, ragged N (tail < 8 and 8..16), ragged M/K, tiny shapes.
    for (m, k, n) in [(1, 1, 1), (8, 48, 16), (33, 47, 29), (64, 192, 128), (17, 300, 21)] {
        let a = DenseTensor::randn(&[m, k], &mut rng);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        let s = with_backend(Backend::Scalar, || dense_gemm::matmul(&a, &b));
        let v = with_backend(Backend::Simd, || dense_gemm::matmul(&a, &b));
        assert!(
            s.allclose(&v, 1e-4, 1e-4),
            "dense {m}x{k}x{n}: max diff {}",
            s.max_abs_diff(&v)
        );
    }
}

#[test]
fn nmg_gemm_parity_scalar_vs_simd() {
    if !simd::have_avx2_fma() {
        return;
    }
    let mut rng = Pcg64::seeded(902);
    for (n, m, g, rows, k, cols) in [
        (1usize, 4usize, 4usize, 16usize, 48usize, 16usize),
        (2, 4, 4, 17, 50, 33),
        (1, 8, 2, 9, 40, 64),
    ] {
        let d = DenseTensor::randn(&[rows, k], &mut rng);
        let a = NmgTensor::from_dense(&d, n, m, g);
        let b = DenseTensor::randn(&[k, cols], &mut rng);
        let s = with_backend(Backend::Scalar, || nmg_gemm::spmm(&a, &b));
        let v = with_backend(Backend::Simd, || nmg_gemm::spmm(&a, &b));
        assert!(
            s.allclose(&v, 1e-4, 1e-4),
            "nmg {n}:{m}:{g} {rows}x{k}x{cols}: max diff {}",
            s.max_abs_diff(&v)
        );
    }
}

#[test]
fn bcsr_gemm_parity_scalar_vs_simd() {
    if !simd::have_avx2_fma() {
        return;
    }
    let mut rng = Pcg64::seeded(903);
    for (bh, bw, rows, k, cols) in [
        (2usize, 4usize, 8usize, 16usize, 32usize),
        (4, 4, 16, 24, 21),
        (8, 8, 16, 32, 48),
        (3, 2, 9, 10, 17),
    ] {
        let mut d = DenseTensor::randn(&[rows, k], &mut rng);
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if i % 3 == 0 {
                *x = 0.0;
            }
        }
        let a = BcsrTensor::from_dense(&d, bh, bw);
        let b = DenseTensor::randn(&[k, cols], &mut rng);
        let s = with_backend(Backend::Scalar, || bcsr_gemm::spmm(&a, &b));
        let v = with_backend(Backend::Simd, || bcsr_gemm::spmm(&a, &b));
        assert!(
            s.allclose(&v, 1e-4, 1e-4),
            "bcsr bh={bh} bw={bw}: max diff {}",
            s.max_abs_diff(&v)
        );
    }
}

#[test]
fn softmax_and_bias_add_are_bit_identical_across_backends() {
    if !simd::have_avx2_fma() {
        return;
    }
    let mut rng = Pcg64::seeded(904);
    for (r, c) in [(3usize, 21usize), (5, 8), (2, 64), (7, 9)] {
        let x = DenseTensor::randn(&[r, c], &mut rng);
        let bias: Vec<f32> = (0..c).map(|_| rng.next_f32() - 0.5).collect();
        let (s_sm, s_ba) = with_backend(Backend::Scalar, || {
            (elementwise::softmax_rows(&x), elementwise::bias_add(&x, &bias))
        });
        let (v_sm, v_ba) = with_backend(Backend::Simd, || {
            (elementwise::softmax_rows(&x), elementwise::bias_add(&x, &bias))
        });
        assert_eq!(s_sm.data(), v_sm.data(), "softmax {r}x{c} diverged bitwise");
        assert_eq!(s_ba.data(), v_ba.data(), "bias_add {r}x{c} diverged bitwise");
    }
}

#[test]
fn layernorm_parity_scalar_vs_simd() {
    if !simd::have_avx2_fma() {
        return;
    }
    let mut rng = Pcg64::seeded(905);
    for (r, c) in [(4usize, 32usize), (3, 19), (1, 8), (6, 7)] {
        let x = DenseTensor::randn(&[r, c], &mut rng);
        let gamma: Vec<f32> = (0..c).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..c).map(|_| rng.next_f32() - 0.5).collect();
        let s = with_backend(Backend::Scalar, || elementwise::layernorm_rows(&x, &gamma, &beta));
        let v = with_backend(Backend::Simd, || elementwise::layernorm_rows(&x, &gamma, &beta));
        assert!(s.allclose(&v, 1e-4, 1e-4), "layernorm {r}x{c}: max diff {}", s.max_abs_diff(&v));
    }
}

#[test]
fn force_guard_applies_and_serializes() {
    // Within a guard the forced backend is globally visible; guards from
    // concurrent tests serialize on the force lock, so these observations
    // are race-free.
    with_backend(Backend::Scalar, || assert_eq!(backend::active(), Backend::Scalar));
    with_backend(Backend::Simd, || assert_eq!(backend::active(), Backend::Simd));
}
