//! Coordinator integration over the artifact runtime: the engine's three
//! FFN modes agree numerically (modulo pruning), both servers deliver every
//! request, batch formation honors `max_wait`, replicas share weights, and
//! the multi-model registry path completes mixed traffic with per-model
//! reports and typed submit errors. Overload defenses are covered end to
//! end: admission rejects and sparse-degrades, load shedding, non-blocking
//! submission (`QueueFull`) and goodput accounting.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sten::coordinator::{
    BatchServer, ConcurrentServer, Engine, FfnMode, ModelRegistry, SchedPolicy, ServeConfig,
    SubmitError,
};
use sten::runtime::ArtifactRuntime;
use sten::util::rng::Pcg64;

fn engine(mode: FfnMode) -> Engine {
    let rt = ArtifactRuntime::open_default().expect("artifact runtime");
    Engine::new(rt, "tiny", mode, 42).unwrap()
}

fn random_request(seq: usize, rng: &mut Pcg64) -> Vec<i32> {
    (0..seq).map(|_| rng.below(100) as i32).collect()
}

#[test]
fn native_dense_ffn_matches_dense_artifact() {
    let mut a = engine(FfnMode::DenseArtifact);
    let mut b = engine(FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(7);
    let tokens = a.random_tokens(&mut rng);
    let la = a.forward(&tokens).unwrap();
    let lb = b.forward(&tokens).unwrap();
    assert!(
        la.allclose(&lb, 2e-2, 2e-2),
        "native dense FFN diverges from artifact FFN: {}",
        la.max_abs_diff(&lb)
    );
}

#[test]
fn block_forward_matches_monolithic_artifact() {
    let mut e = engine(FfnMode::DenseArtifact);
    let mut rng = Pcg64::seeded(8);
    let tokens = e.random_tokens(&mut rng);
    let block = e.forward(&tokens).unwrap();
    let mono = e.forward_monolithic(&tokens).unwrap();
    assert!(
        block.allclose(&mono, 2e-2, 2e-2),
        "block-composed forward diverges from monolithic: {}",
        block.max_abs_diff(&mono)
    );
}

#[test]
fn nmg_mode_serves_the_pruned_network() {
    // After set_ffn_mode(NativeNmg), the engine serves the *pruned* weights;
    // running the same pruned weights through the dense path must agree.
    let mut sparse = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let mut rng = Pcg64::seeded(9);
    let tokens = sparse.random_tokens(&mut rng);
    let ls = sparse.forward(&tokens).unwrap();
    // NativeDense over the engine's (already pruned) parameters.
    sparse.ffn_mode = FfnMode::NativeDense;
    let ld = sparse.forward(&tokens).unwrap();
    assert!(
        ls.allclose(&ld, 2e-2, 2e-2),
        "nmg kernel diverges from dense over pruned weights: {}",
        ls.max_abs_diff(&ld)
    );
}

#[test]
fn timing_breakdown_populated_per_mode() {
    let mut e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let mut rng = Pcg64::seeded(10);
    let tokens = e.random_tokens(&mut rng);
    e.forward(&tokens).unwrap();
    let t = e.timing();
    assert!(t.secs("runtime") > 0.0, "runtime bucket empty");
    assert!(t.secs("native") > 0.0, "native bucket empty");
}

#[test]
fn batch_server_completes_all_requests() {
    let e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    let mut server = BatchServer::new(e, Duration::from_millis(1));
    let mut rng = Pcg64::seeded(11);
    let total = batch * 2 + 1; // forces a padded final batch
    for _ in 0..total {
        server.submit(&random_request(seq, &mut rng));
    }
    server.run_until_drained().unwrap();
    assert_eq!(server.completed.len(), total);
    assert!(server.median_latency().unwrap() > 0.0);
    assert!(server.throughput().unwrap() > 0.0);
    // Batch sizes never exceed the artifact batch.
    assert!(server.completed.iter().all(|r| r.batch_size <= batch));
}

#[test]
fn server_clamps_and_pads_tokens() {
    let e = engine(FfnMode::NativeDense);
    let seq = e.dims.seq;
    let mut server = BatchServer::new(e, Duration::from_millis(1));
    // Out-of-vocab and short sequences must be handled.
    server.submit(&[-5, 999_999]);
    server.submit(&vec![3; seq * 2]);
    server.run_until_drained().unwrap();
    assert_eq!(server.completed.len(), 2);
}

#[test]
fn sync_server_dispatches_lone_request_once_max_wait_elapses() {
    // Regression: run_one_batch used to ignore max_wait entirely.
    let e = engine(FfnMode::NativeDense);
    let mut server = BatchServer::new(e, Duration::from_millis(80));
    server.submit(&[1, 2, 3]);
    let t = Instant::now();
    let out = server.run_one_batch().unwrap();
    assert!(out.is_some());
    let waited = t.elapsed();
    assert!(
        waited >= Duration::from_millis(60),
        "partial batch dispatched before max_wait: {waited:?}"
    );
    let r = &server.completed[0];
    assert!(r.queue_s >= 0.06, "queue_s {} does not reflect the deadline wait", r.queue_s);
    assert_eq!(r.batch_size, 1);
}

#[test]
fn sync_server_throughput_counts_each_batch_by_id() {
    // Regression: throughput() used to dedup batches by compute_s bit
    // pattern, merging distinct batches with identical timings.
    let e = engine(FfnMode::NativeDense);
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    let mut server = BatchServer::new(e, Duration::from_millis(1));
    let mut rng = Pcg64::seeded(12);
    for _ in 0..batch * 2 {
        server.submit(&random_request(seq, &mut rng));
    }
    server.run_until_drained().unwrap();
    let ids: HashSet<u64> = server.completed.iter().map(|r| r.batch_id).collect();
    assert_eq!(ids.len(), 2, "expected two distinct batch ids");
    assert!(server.throughput().unwrap() > 0.0);
}

#[test]
fn replicas_share_weights_until_reconfigured() {
    let mut a = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let mut b = a.replicate();
    assert!(a.shares_weights_with(&b));
    assert_eq!(a.param("layer0.w1"), b.param("layer0.w1"));

    // Replicas produce identical logits over the shared pruned weights.
    let mut rng = Pcg64::seeded(20);
    let tokens = a.random_tokens(&mut rng);
    let la = a.forward(&tokens).unwrap();
    let lb = b.forward(&tokens).unwrap();
    assert!(la.allclose(&lb, 0.0, 0.0), "replicas diverged: {}", la.max_abs_diff(&lb));

    // Reconfiguring one replica copies-on-write; others keep sharing.
    let mut c = a.replicate();
    c.set_ffn_mode(FfnMode::NativeDense);
    assert!(!a.shares_weights_with(&c));
    assert!(a.shares_weights_with(&b));
}

#[test]
fn concurrent_server_completes_every_request_exactly_once() {
    let e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    // queue_cap below the request count exercises submit backpressure.
    let cfg = ServeConfig {
        replicas: 2,
        queue_cap: batch.max(2),
        max_wait: Duration::from_millis(5),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let total = batch * 3;
    let mut rng = Pcg64::seeded(31);
    let mut submitted = Vec::new();
    for _ in 0..total {
        submitted.push(server.submit(&random_request(seq, &mut rng)).unwrap());
    }
    let report = server.finish().unwrap();

    assert_eq!(report.results.len(), total, "every request gets exactly one completion");
    let mut seen: Vec<u64> = report.results.iter().map(|r| r.id).collect();
    seen.sort_unstable();
    submitted.sort_unstable();
    assert_eq!(seen, submitted, "completion ids != submitted ids");

    assert!(report.results.iter().all(|r| r.batch_size >= 1 && r.batch_size <= batch));
    let riders: usize = {
        let mut per_batch: std::collections::HashMap<u64, usize> = Default::default();
        for r in &report.results {
            per_batch.insert(r.batch_id, r.batch_size);
        }
        per_batch.values().sum()
    };
    assert_eq!(riders, total, "per-batch rider counts must partition the requests");

    let lat = report.latency.expect("latency summary");
    assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99, "percentiles out of order: {lat:?}");
    assert!(report.batches >= (total / batch) as u64);
    assert!(report.queue_high_water >= 1);
    assert!(report.wall_rps > 0.0);
}

#[test]
fn concurrent_lone_request_dispatches_once_max_wait_elapses() {
    let e = engine(FfnMode::NativeDense);
    let cfg = ServeConfig {
        replicas: 2,
        queue_cap: 8,
        max_wait: Duration::from_millis(120),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let t = Instant::now();
    server.submit(&[1, 2, 3]).unwrap();
    server.drain();
    let elapsed = t.elapsed();
    assert!(
        elapsed >= Duration::from_millis(100),
        "lone request dispatched before its deadline: {elapsed:?}"
    );
    let results = server.completed();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].batch_size, 1);
    assert!(results[0].queue_s >= 0.1, "queue_s {}", results[0].queue_s);
    assert!(results[0].queue_s <= 1.5, "waited far past max_wait: {}", results[0].queue_s);
    server.finish().unwrap();
}

#[test]
fn concurrent_full_batch_dispatches_immediately() {
    let e = engine(FfnMode::NativeDense);
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    // Huge max_wait: only the full-batch fast path can finish quickly.
    let cfg = ServeConfig {
        replicas: 1,
        queue_cap: 8,
        max_wait: Duration::from_secs(5),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let mut rng = Pcg64::seeded(33);
    let t = Instant::now();
    for _ in 0..batch {
        server.submit(&random_request(seq, &mut rng)).unwrap();
    }
    server.drain();
    let elapsed = t.elapsed();
    assert!(
        elapsed < Duration::from_secs(2),
        "full batch waited on the deadline: {elapsed:?}"
    );
    let report = server.finish().unwrap();
    assert!(report.results.iter().all(|r| r.batch_size == batch));
    assert!(report.results.iter().all(|r| r.queue_s < 2.5));
}

#[test]
fn submit_to_unknown_model_is_a_typed_error() {
    let e = engine(FfnMode::NativeDense);
    let cfg = ServeConfig {
        replicas: 1,
        queue_cap: 8,
        max_wait: Duration::from_millis(1),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let err = server.submit_to("nope", &[1, 2]).unwrap_err();
    assert_eq!(err, SubmitError::UnknownModel("nope".to_string()));
    // The single-model `start` path registers under "default"; both the
    // named and the legacy submit keep working after the rejection.
    assert_eq!(server.models().to_vec(), vec!["default".to_string()]);
    server.submit_to("default", &[1, 2]).unwrap();
    server.submit(&[3, 4]).unwrap();
    let report = server.finish().unwrap();
    assert_eq!(report.results.len(), 2, "rejected submits must not be counted");
}

#[test]
fn multi_model_server_completes_mixed_traffic_with_per_model_reports() {
    let rt = Arc::new(ArtifactRuntime::open_default().expect("artifact runtime"));
    let dense = Engine::with_runtime(rt.clone(), "tiny", FfnMode::NativeDense, 42).unwrap();
    let nmg =
        Engine::with_runtime(rt.clone(), "tiny", FfnMode::NativeNmg { n: 2, m: 4, g: 4 }, 43)
            .unwrap();
    assert!(!dense.shares_weights_with(&nmg), "models keep separate weight sets");
    let batch = dense.dims.batch;
    let seq = dense.dims.seq;

    let mut registry = ModelRegistry::new();
    registry.register("dense", dense, 1, 1).unwrap();
    registry.register("nmg", nmg, 1, 3).unwrap();
    let cfg = ServeConfig {
        queue_cap: 32,
        max_wait: Duration::from_millis(2),
        policy: SchedPolicy::Wdrr,
        slo: Duration::from_secs(30),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start_registry(registry, cfg).unwrap();

    let mut rng = Pcg64::seeded(51);
    let total = batch * 6;
    let mut dense_count = 0usize;
    for i in 0..total {
        let toks = random_request(seq, &mut rng);
        if i % 3 == 0 {
            dense_count += 1;
            server.submit_to("dense", &toks).unwrap();
        } else {
            server.submit_to("nmg", &toks).unwrap();
        }
    }
    let report = server.finish().unwrap();

    assert_eq!(report.results.len(), total, "every request completes exactly once");
    let ids: HashSet<u64> = report.results.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), total, "duplicate completion ids");
    // Batches never mix models, and sizes respect each model's batch.
    let mut batch_models: std::collections::HashMap<u64, usize> = Default::default();
    for r in &report.results {
        assert!(r.batch_size >= 1 && r.batch_size <= batch);
        let prev = batch_models.insert(r.batch_id, r.model);
        if let Some(prev) = prev {
            assert_eq!(prev, r.model, "batch {} mixed models", r.batch_id);
        }
    }

    assert_eq!(report.per_model.len(), 2);
    assert_eq!(report.per_model[0].name, "dense");
    assert_eq!(report.per_model[1].name, "nmg");
    assert_eq!(report.per_model[0].metrics.requests, dense_count);
    assert_eq!(report.per_model[1].metrics.requests, total - dense_count);
    for m in &report.per_model {
        let lat = m.metrics.latency.expect("per-model latency");
        assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
        let miss = m.metrics.slo_miss.expect("per-model slo-miss");
        assert!((0.0..=1.0).contains(&miss));
        assert!(m.metrics.batches >= 1);
        assert!(m.queue_high_water >= 1);
    }
    // A 30s SLO is unmissable for tiny batches on a live host.
    assert_eq!(report.slo_miss, Some(0.0));
    // Two workers (one per registered replica), each with a timing view.
    assert_eq!(report.replica_timing.len(), 2);
}

#[test]
fn admission_rejects_once_the_estimate_blows_the_slo() {
    // An impossible SLO (zero) with admission on: everything is admitted
    // until the first completion calibrates the service-time EWMA; after
    // that every prediction exceeds the SLO and — with no degrade target
    // registered — submits are rejected, typed and counted.
    let e = engine(FfnMode::NativeDense);
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    // Large max_wait: the priming submissions dispatch only as one full
    // batch, so the EWMA cannot calibrate (and start rejecting) while the
    // priming loop is still submitting on a slow host.
    let cfg = ServeConfig {
        replicas: 1,
        queue_cap: 32,
        max_wait: Duration::from_millis(500),
        slo: Duration::ZERO,
        admission: true,
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let mut rng = Pcg64::seeded(60);
    for _ in 0..batch {
        server.submit(&random_request(seq, &mut rng)).unwrap();
    }
    server.drain();
    assert!(server.service_estimate(0) > 0.0, "drain must have calibrated the EWMA");
    assert!(server.predicted_wait(0) > Duration::ZERO);

    let err = server.submit(&random_request(seq, &mut rng)).unwrap_err();
    match err {
        SubmitError::Rejected { predicted } => assert!(predicted > Duration::ZERO),
        other => panic!("expected Rejected, got {other:?}"),
    }
    let report = server.finish().unwrap();
    assert_eq!(report.results.len(), batch, "rejected submits must not complete");
    assert_eq!(report.rejected, 1);
    assert_eq!(report.per_model[0].rejected, 1);
    assert_eq!(report.shed, 0);
    assert_eq!(report.degraded, 0);
}

#[test]
fn admission_degrades_to_the_registered_sparse_variant() {
    let rt = Arc::new(ArtifactRuntime::open_default().expect("artifact runtime"));
    let dense = Engine::with_runtime(rt.clone(), "tiny", FfnMode::NativeDense, 42).unwrap();
    let nmg =
        Engine::with_runtime(rt.clone(), "tiny", FfnMode::NativeNmg { n: 2, m: 4, g: 4 }, 43)
            .unwrap();
    let batch = dense.dims.batch;
    let seq = dense.dims.seq;
    let mut registry = ModelRegistry::new();
    registry.register("dense", dense, 1, 1).unwrap();
    registry.register("nmg", nmg, 1, 1).unwrap();
    registry.set_degrade("dense", "nmg").unwrap();
    // Large max_wait for the same priming-race reason as the rejection
    // test: dense primes as one full batch or not at all.
    let cfg = ServeConfig {
        queue_cap: 32,
        max_wait: Duration::from_millis(500),
        slo: Duration::ZERO,
        admission: true,
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start_registry(registry, cfg).unwrap();

    // Prime the dense EWMA; the nmg variant stays unobserved (estimate 0),
    // so its prediction still fits the impossible SLO.
    let mut rng = Pcg64::seeded(61);
    for _ in 0..batch {
        server.submit_to("dense", &random_request(seq, &mut rng)).unwrap();
    }
    server.drain();
    assert!(server.service_estimate(0) > 0.0);

    // Every further dense request degrades to nmg — until an nmg batch
    // completes and calibrates *its* estimate too, after which requests
    // are rejected. Both outcomes are legitimate; the first submit must
    // degrade (nothing nmg has run yet).
    let mut degraded_ids = Vec::new();
    for _ in 0..4 {
        match server.submit_to("dense", &random_request(seq, &mut rng)) {
            Ok(id) => degraded_ids.push(id),
            Err(SubmitError::Rejected { predicted }) => assert!(predicted > Duration::ZERO),
            Err(other) => panic!("unexpected submit error: {other:?}"),
        }
    }
    assert!(!degraded_ids.is_empty(), "the first post-prime submit must degrade");
    server.drain();
    let report = server.finish().unwrap();

    // Degraded requests complete under the *target* model; the degrade
    // count stays with the model the client asked for.
    for id in &degraded_ids {
        let r = report.results.iter().find(|r| r.id == *id).expect("degraded completion");
        assert_eq!(r.model, 1, "request {id} should have been served by nmg");
    }
    assert_eq!(report.degraded, degraded_ids.len() as u64);
    assert_eq!(report.per_model[0].degraded, degraded_ids.len() as u64);
    assert_eq!(report.per_model[1].degraded, 0);
    assert_eq!(report.per_model[0].rejected, 4 - degraded_ids.len() as u64);
    assert_eq!(report.per_model[0].metrics.requests, batch);
    assert_eq!(report.per_model[1].metrics.requests, degraded_ids.len());
    assert_eq!(report.results.len(), batch + degraded_ids.len());
}

#[test]
fn shedding_drops_requests_already_past_the_slo() {
    // A zero SLO with shedding on: every queued entry is a guaranteed miss
    // by the time a worker sees it, so nothing may reach an engine — all
    // requests are shed, accounted, and drain() still returns.
    let e = engine(FfnMode::NativeDense);
    let seq = e.dims.seq;
    let cfg = ServeConfig {
        replicas: 2,
        queue_cap: 32,
        max_wait: Duration::from_millis(2),
        slo: Duration::ZERO,
        shed: true,
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let mut rng = Pcg64::seeded(62);
    let total = 6usize;
    for _ in 0..total {
        server.submit(&random_request(seq, &mut rng)).unwrap();
    }
    server.drain(); // sheds are accounted: this must not hang
    let report = server.finish().unwrap();
    assert!(report.results.is_empty(), "shed requests must never execute");
    assert_eq!(report.shed, total as u64);
    assert_eq!(report.per_model[0].shed, total as u64);
    assert_eq!(report.batches, 0, "no batch may form from expired entries");
    assert_eq!(report.goodput_rps, 0.0);
}

#[test]
fn try_submit_surfaces_queue_full_instead_of_blocking() {
    // A capacity-1 submission queue and a single worker: a tight submit
    // loop outruns service and must see QueueFull (never a block). Every
    // accepted request still completes exactly once.
    let e = engine(FfnMode::NativeDense);
    let seq = e.dims.seq;
    let cfg = ServeConfig {
        replicas: 1,
        queue_cap: 1,
        max_wait: Duration::from_millis(1),
        slo: Duration::from_secs(30),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let mut rng = Pcg64::seeded(63);
    let mut accepted = 0usize;
    let mut saw_full = false;
    for _ in 0..50_000 {
        match server.try_submit(&random_request(seq, &mut rng)) {
            Ok(_) => accepted += 1,
            Err(SubmitError::QueueFull) => {
                saw_full = true;
                break;
            }
            Err(other) => panic!("unexpected submit error: {other:?}"),
        }
    }
    assert!(saw_full, "a tight loop never saturated a capacity-1 queue");
    server.drain();
    let report = server.finish().unwrap();
    assert_eq!(report.results.len(), accepted, "accepted requests must all complete");
    assert_eq!(report.shed + report.rejected + report.degraded, 0);
}

#[test]
fn goodput_matches_wall_rate_when_every_request_is_in_slo() {
    let e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    let cfg = ServeConfig { slo: Duration::from_secs(30), ..ServeConfig::default() };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let mut rng = Pcg64::seeded(64);
    for _ in 0..batch * 2 {
        server.submit(&random_request(seq, &mut rng)).unwrap();
    }
    let report = server.finish().unwrap();
    assert_eq!(report.results.len(), batch * 2);
    assert!(report.goodput_rps > 0.0);
    // With a 30s SLO every completion is goodput.
    assert!(
        (report.goodput_rps - report.wall_rps).abs() < 1e-6 * report.wall_rps.max(1.0),
        "goodput {} != wall rate {}",
        report.goodput_rps,
        report.wall_rps
    );
    assert_eq!(report.shed + report.rejected + report.degraded, 0);
}

#[test]
fn concurrent_queue_wait_bounded_by_max_wait() {
    let e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    let max_wait = Duration::from_millis(40);
    let cfg = ServeConfig { replicas: 2, queue_cap: 8, max_wait, ..ServeConfig::default() };
    let server = ConcurrentServer::start(e, cfg).unwrap();
    let mut rng = Pcg64::seeded(34);
    for _ in 0..batch * 3 + 1 {
        server.submit(&random_request(seq, &mut rng)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let report = server.finish().unwrap();
    // Under light load no request waits in queue longer than max_wait
    // before its batch is formed (generous slack for loaded CI hosts).
    let bound = max_wait.as_secs_f64() + 0.45;
    for r in &report.results {
        assert!(
            r.queue_s <= bound,
            "request {} waited {:.3}s for batch formation (max_wait {:?})",
            r.id,
            r.queue_s,
            max_wait
        );
    }
}
