//! Coordinator integration over the real AOT artifacts: the engine's three
//! FFN modes agree numerically (modulo pruning), the batch server delivers
//! every request, and the timing breakdown is populated.

use std::time::Duration;

use sten::coordinator::{BatchServer, Engine, FfnMode};
use sten::runtime::ArtifactRuntime;
use sten::util::rng::Pcg64;

fn engine(mode: FfnMode) -> Engine {
    let rt = ArtifactRuntime::open_default().expect("run `make artifacts` first");
    Engine::new(rt, "tiny", mode, 42).unwrap()
}

#[test]
fn native_dense_ffn_matches_dense_artifact() {
    let mut a = engine(FfnMode::DenseArtifact);
    let mut b = engine(FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(7);
    let tokens = a.random_tokens(&mut rng);
    let la = a.forward(&tokens).unwrap();
    let lb = b.forward(&tokens).unwrap();
    assert!(
        la.allclose(&lb, 2e-2, 2e-2),
        "native dense FFN diverges from artifact FFN: {}",
        la.max_abs_diff(&lb)
    );
}

#[test]
fn block_forward_matches_monolithic_artifact() {
    let mut e = engine(FfnMode::DenseArtifact);
    let mut rng = Pcg64::seeded(8);
    let tokens = e.random_tokens(&mut rng);
    let block = e.forward(&tokens).unwrap();
    let mono = e.forward_monolithic(&tokens).unwrap();
    assert!(
        block.allclose(&mono, 2e-2, 2e-2),
        "block-composed forward diverges from monolithic: {}",
        block.max_abs_diff(&mono)
    );
}

#[test]
fn nmg_mode_serves_the_pruned_network() {
    // After set_ffn_mode(NativeNmg), the engine serves the *pruned* weights;
    // running the same pruned weights through the dense path must agree.
    let mut sparse = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let mut rng = Pcg64::seeded(9);
    let tokens = sparse.random_tokens(&mut rng);
    let ls = sparse.forward(&tokens).unwrap();
    // NativeDense over the engine's (already pruned) parameters.
    sparse.ffn_mode = FfnMode::NativeDense;
    let ld = sparse.forward(&tokens).unwrap();
    assert!(
        ls.allclose(&ld, 2e-2, 2e-2),
        "nmg kernel diverges from dense over pruned weights: {}",
        ls.max_abs_diff(&ld)
    );
}

#[test]
fn timing_breakdown_populated_per_mode() {
    let mut e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let mut rng = Pcg64::seeded(10);
    let tokens = e.random_tokens(&mut rng);
    e.forward(&tokens).unwrap();
    let t = e.timing();
    assert!(t.secs("runtime") > 0.0, "runtime bucket empty");
    assert!(t.secs("native") > 0.0, "native bucket empty");
}

#[test]
fn batch_server_completes_all_requests() {
    let e = engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let batch = e.dims.batch;
    let seq = e.dims.seq;
    let mut server = BatchServer::new(e, Duration::from_millis(1));
    let mut rng = Pcg64::seeded(11);
    let total = batch * 2 + 1; // forces a padded final batch
    for _ in 0..total {
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(100) as i32).collect();
        server.submit(&toks);
    }
    server.run_until_drained().unwrap();
    assert_eq!(server.completed.len(), total);
    assert!(server.median_latency().unwrap() > 0.0);
    assert!(server.throughput().unwrap() > 0.0);
    // Batch sizes never exceed the artifact batch.
    assert!(server.completed.iter().all(|r| r.batch_size <= batch));
}

#[test]
fn server_clamps_and_pads_tokens() {
    let e = engine(FfnMode::NativeDense);
    let seq = e.dims.seq;
    let mut server = BatchServer::new(e, Duration::from_millis(1));
    // Out-of-vocab and short sequences must be handled.
    server.submit(&[-5, 999_999]);
    server.submit(&vec![3; seq * 2]);
    server.run_until_drained().unwrap();
    assert_eq!(server.completed.len(), 2);
}
