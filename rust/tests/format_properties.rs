//! Property-based round-trip tests for every sparse format.
//!
//! Sparse-format correctness bugs are subtle (Hoefler et al., 2021): an
//! off-by-one in an indptr, a dropped explicit zero, or a mis-permuted
//! chunk silently corrupts downstream numerics. These nets check, over
//! randomized shapes and densities:
//!
//! * exact-compression formats (CSR/CSC/COO/ELL/BCSR/Masked): dense ->
//!   format -> dense is bit-exact;
//! * structured formats (n:m, n:m:g): pruning preserves kept values
//!   verbatim, respects the structural budget, and (n:m) is idempotent —
//!   a conforming dense round-trips exactly;
//! * n:m:g flat (de)serialization (`val_flat`/`idx_flat` -> `from_flat`)
//!   is exact;
//! * `convert.rs` cross-format paths agree with the source's `to_dense`.

use sten::formats::{
    convert, AnyTensor, BcsrTensor, CooTensor, CscTensor, CsrTensor, EllTensor, Layout,
    MaskedTensor, NmTensor, NmgTensor,
};
use sten::tensor::DenseTensor;
use sten::util::proptest;
use sten::util::rng::Pcg64;

/// Random (rows x cols) dense matrix with ~`density` nonzero fraction.
fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f32) -> DenseTensor {
    let data = (0..rows * cols)
        .map(|_| if rng.next_f32() < density { rng.normal() } else { 0.0 })
        .collect();
    DenseTensor::from_vec(&[rows, cols], data)
}

#[test]
fn prop_exact_formats_roundtrip_exactly() {
    proptest::check(
        "exact-format-roundtrip",
        40,
        |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(24) as usize;
            let density = rng.next_f32();
            (rows, cols, density, rng.next_u64())
        },
        |&(rows, cols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = random_sparse(&mut rng, rows, cols, density);
            let back = [
                CsrTensor::from_dense(&d).to_dense(),
                CscTensor::from_dense(&d).to_dense(),
                CooTensor::from_dense(&d).to_dense(),
                EllTensor::from_dense(&d).to_dense(),
                MaskedTensor::from_dense(&d).to_dense(),
            ];
            back.iter().all(|b| b.allclose(&d, 0.0, 0.0))
        },
    );
}

#[test]
fn prop_bcsr_roundtrips_exactly_on_divisible_shapes() {
    proptest::check(
        "bcsr-roundtrip",
        30,
        |rng| {
            let bh = 1 + rng.below(4) as usize;
            let bw = 1 + rng.below(4) as usize;
            let rows = bh * (1 + rng.below(6) as usize);
            let cols = bw * (1 + rng.below(6) as usize);
            let density = rng.next_f32();
            (bh, bw, rows, cols, density, rng.next_u64())
        },
        |&(bh, bw, rows, cols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = random_sparse(&mut rng, rows, cols, density);
            BcsrTensor::from_dense(&d, bh, bw).to_dense().allclose(&d, 0.0, 0.0)
        },
    );
}

#[test]
fn prop_nm_preserves_kept_values_and_is_idempotent() {
    proptest::check(
        "nm-roundtrip",
        30,
        |rng| {
            let m = [2usize, 4, 8][rng.below(3) as usize];
            let n = 1 + rng.below(m as u32) as usize;
            let rows = m * (1 + rng.below(5) as usize);
            let cols = 1 + rng.below(12) as usize;
            (n, m, rows, cols, rng.next_u64())
        },
        |&(n, m, rows, cols, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = random_sparse(&mut rng, rows, cols, 0.8);
            let pruned = NmTensor::from_dense(&d, n, m).to_dense();
            // Every surviving value is the original, untouched.
            for r in 0..rows {
                for c in 0..cols {
                    let v = pruned.get2(r, c);
                    if v != 0.0 && v != d.get2(r, c) {
                        return false;
                    }
                }
            }
            // Structural budget: at most n nonzeros per (m-block, column).
            for b in 0..rows / m {
                for c in 0..cols {
                    let nnz = (0..m).filter(|&i| pruned.get2(b * m + i, c) != 0.0).count();
                    if nnz > n {
                        return false;
                    }
                }
            }
            // A conforming dense round-trips exactly (idempotence).
            NmTensor::from_dense(&pruned, n, m).to_dense().allclose(&pruned, 0.0, 0.0)
        },
    );
}

#[test]
fn prop_nmg_preserves_kept_values_and_flat_roundtrip_is_exact() {
    proptest::check(
        "nmg-roundtrip",
        25,
        |rng| {
            let fmts = [(2usize, 4usize, 2usize), (1, 4, 4), (2, 8, 2), (1, 8, 1)];
            let (n, m, g) = fmts[rng.below(4) as usize];
            let rows = m * (1 + rng.below(4) as usize);
            let cols = 1 + rng.below(40) as usize;
            (n, m, g, rows, cols, rng.next_u64())
        },
        |&(n, m, g, rows, cols, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = random_sparse(&mut rng, rows, cols, 0.9);
            let t = NmgTensor::from_dense(&d, n, m, g);
            let pruned = t.to_dense();
            // Kept values are verbatim; per-column budget holds per slab.
            for r in 0..rows {
                for c in 0..cols {
                    let v = pruned.get2(r, c);
                    if v != 0.0 && v != d.get2(r, c) {
                        return false;
                    }
                }
            }
            for s in 0..rows / m {
                for c in 0..cols {
                    let nnz = (0..m).filter(|&i| pruned.get2(s * m + i, c) != 0.0).count();
                    if nnz > n {
                        return false;
                    }
                }
            }
            // Flat serialization round-trips the format exactly.
            let idx: Vec<u32> = t.idx_flat().to_vec();
            let rebuilt = NmgTensor::from_flat(
                [rows, cols],
                n,
                m,
                g,
                t.val_flat().to_vec(),
                idx,
            );
            rebuilt.to_dense().allclose(&pruned, 0.0, 0.0)
        },
    );
}

#[test]
fn prop_lossless_conversions_agree_across_formats() {
    proptest::check(
        "convert-cross-format-agreement",
        25,
        |rng| {
            let rows = 1 + rng.below(16) as usize;
            let cols = 1 + rng.below(16) as usize;
            let density = rng.next_f32();
            (rows, cols, density, rng.next_u64())
        },
        |&(rows, cols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = random_sparse(&mut rng, rows, cols, density);
            let sources: Vec<AnyTensor> = vec![
                AnyTensor::Dense(d.clone()),
                AnyTensor::Csr(CsrTensor::from_dense(&d)),
                AnyTensor::Csc(CscTensor::from_dense(&d)),
                AnyTensor::Coo(CooTensor::from_dense(&d)),
                AnyTensor::Ell(EllTensor::from_dense(&d)),
                AnyTensor::Masked(MaskedTensor::from_dense(&d)),
            ];
            let targets =
                [Layout::Dense, Layout::Csr, Layout::Csc, Layout::Coo, Layout::Ell, Layout::Masked];
            for src in &sources {
                let want = src.to_dense();
                for &target in &targets {
                    match convert::lossless(src, target) {
                        Some(conv) => {
                            if conv.layout() != target
                                || !conv.to_dense().allclose(&want, 0.0, 0.0)
                            {
                                return false;
                            }
                        }
                        None => return false, // all exact targets must be offered
                    }
                }
                // Structured targets need sparsifiers: never offered.
                if convert::lossless(src, Layout::Nm).is_some()
                    || convert::lossless(src, Layout::Nmg).is_some()
                {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_structured_sources_escape_losslessly() {
    proptest::check(
        "nmg-escape-lossless",
        20,
        |rng| {
            let rows = 4 * (1 + rng.below(4) as usize);
            let cols = 1 + rng.below(24) as usize;
            (rows, cols, rng.next_u64())
        },
        |&(rows, cols, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = DenseTensor::randn(&[rows, cols], &mut rng);
            let src = AnyTensor::Nmg(NmgTensor::from_dense(&d, 2, 4, 2));
            let want = src.to_dense();
            [Layout::Dense, Layout::Csr, Layout::Csc, Layout::Coo, Layout::Ell, Layout::Masked]
                .iter()
                .all(|&target| match convert::lossless(&src, target) {
                    Some(conv) => conv.to_dense().allclose(&want, 0.0, 0.0),
                    None => false,
                })
        },
    );
}

#[test]
fn all_zero_and_single_element_edge_cases() {
    // Degenerate inputs exercise empty index arrays and width-0 ELL.
    for d in [DenseTensor::zeros(&[4, 8]), DenseTensor::zeros(&[1, 1]), DenseTensor::ones(&[1, 1])]
    {
        assert!(CsrTensor::from_dense(&d).to_dense().allclose(&d, 0.0, 0.0));
        assert!(CscTensor::from_dense(&d).to_dense().allclose(&d, 0.0, 0.0));
        assert!(CooTensor::from_dense(&d).to_dense().allclose(&d, 0.0, 0.0));
        assert!(EllTensor::from_dense(&d).to_dense().allclose(&d, 0.0, 0.0));
        assert!(MaskedTensor::from_dense(&d).to_dense().allclose(&d, 0.0, 0.0));
        assert!(BcsrTensor::from_dense(&d, 1, 1).to_dense().allclose(&d, 0.0, 0.0));
    }
}
