//! Property-based kernel equivalence: every sparse GEMM matches the naive
//! dense reference on randomized shapes and sparsities within 1e-5,
//! including empty-row and all-zero edge cases. This is the Nerva lesson
//! (Wesselink et al., 2024): truly-sparse kernels only pay off if they are
//! *exactly* as correct as the dense path they replace.

use sten::formats::{BcsrTensor, CscTensor, CsrTensor, EllTensor, NmgTensor};
use sten::kernels::{bcsr_gemm, csc_gemm, csr_gemm, dense_gemm, ell_gemm, nmg_gemm};
use sten::tensor::DenseTensor;
use sten::util::proptest;
use sten::util::rng::Pcg64;

const TOL: f32 = 1e-5;

/// Random (rows x cols) dense matrix with ~`density` nonzero fraction.
fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f32) -> DenseTensor {
    let data = (0..rows * cols)
        .map(|_| if rng.next_f32() < density { rng.normal() } else { 0.0 })
        .collect();
    DenseTensor::from_vec(&[rows, cols], data)
}

/// Zero out an entire row (the empty-row edge case every row-indexed kernel
/// must survive: empty indptr span, zero ELL occupancy, missing blocks).
fn clear_row(d: &mut DenseTensor, r: usize) {
    let cols = d.cols();
    for c in 0..cols {
        d.set2(r, c, 0.0);
    }
}

fn gen_case(rng: &mut Pcg64) -> (usize, usize, usize, f32, u64) {
    let m = 1 + rng.below(32) as usize;
    let k = 1 + rng.below(48) as usize;
    let n = 1 + rng.below(32) as usize;
    // Sweep the density range including fully-empty matrices.
    let density = [0.0f32, 0.05, 0.3, 0.7, 1.0][rng.below(5) as usize];
    (m, k, n, density, rng.next_u64())
}

#[test]
fn prop_dense_blocked_matches_naive() {
    proptest::check("dense-gemm-vs-naive", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let a = random_sparse(&mut rng, m, k, density);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        dense_gemm::matmul(&a, &b).allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
    });
}

#[test]
fn prop_csr_matches_dense() {
    proptest::check("csr-gemm-vs-dense", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let mut a = random_sparse(&mut rng, m, k, density);
        clear_row(&mut a, rng.below(m as u32) as usize);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        let got = csr_gemm::spmm(&CsrTensor::from_dense(&a), &b);
        got.allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
    });
}

#[test]
fn prop_csc_matches_dense() {
    proptest::check("csc-gemm-vs-dense", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let a = DenseTensor::randn(&[m, k], &mut rng);
        let mut w = random_sparse(&mut rng, k, n, density);
        clear_row(&mut w, rng.below(k as u32) as usize);
        let got = csc_gemm::spmm_dense_csc(&a, &CscTensor::from_dense(&w));
        got.allclose(&dense_gemm::matmul_naive(&a, &w), TOL, TOL)
    });
}

#[test]
fn prop_ell_matches_dense() {
    proptest::check("ell-gemm-vs-dense", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let mut a = random_sparse(&mut rng, m, k, density);
        // Skew the occupancy: one empty row plus one (possibly) full row.
        clear_row(&mut a, rng.below(m as u32) as usize);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        let got = ell_gemm::spmm(&EllTensor::from_dense(&a), &b);
        got.allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
    });
}

#[test]
fn prop_bcsr_matches_dense() {
    proptest::check(
        "bcsr-gemm-vs-dense",
        25,
        |rng| {
            let bh = 1 + rng.below(4) as usize;
            let bw = 1 + rng.below(4) as usize;
            let m = bh * (1 + rng.below(6) as usize);
            let k = bw * (1 + rng.below(6) as usize);
            let n = 1 + rng.below(24) as usize;
            let density = [0.0f32, 0.2, 0.8][rng.below(3) as usize];
            (bh, bw, m, k, n, density, rng.next_u64())
        },
        |&(bh, bw, m, k, n, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut a = random_sparse(&mut rng, m, k, density);
            clear_row(&mut a, rng.below(m as u32) as usize);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            let got = bcsr_gemm::spmm(&BcsrTensor::from_dense(&a, bh, bw), &b);
            got.allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
        },
    );
}

#[test]
fn prop_nmg_matches_dense_over_pruned_weights() {
    proptest::check(
        "nmg-gemm-vs-dense",
        20,
        |rng| {
            let fmts = [(2usize, 4usize, 4usize), (1, 4, 2), (2, 8, 2)];
            let (nn, m, g) = fmts[rng.below(3) as usize];
            let slabs = 1 + rng.below(3) as usize;
            let k = 1 + rng.below(48) as usize;
            let ncols = 1 + rng.below(32) as usize;
            let density = [0.0f32, 0.4, 1.0][rng.below(3) as usize];
            (nn, m, g, slabs, k, ncols, density, rng.next_u64())
        },
        |&(nn, m, g, slabs, k, ncols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut d = random_sparse(&mut rng, slabs * m, k, density);
            clear_row(&mut d, rng.below((slabs * m) as u32) as usize);
            // The n:m:g kernel reorders columns by pattern, so unlike the
            // row-ordered kernels its summation order genuinely differs from
            // the reference; halve the operand scale to keep accumulated
            // rounding far inside the 1e-5 window.
            d.scale(0.5);
            let a = NmgTensor::from_dense(&d, nn, m, g);
            let mut b = DenseTensor::randn(&[k, ncols], &mut rng);
            b.scale(0.5);
            // The kernel must match the dense GEMM over the *pruned* matrix.
            let got = nmg_gemm::spmm(&a, &b);
            got.allclose(&dense_gemm::matmul_naive(&a.to_dense(), &b), TOL, TOL)
        },
    );
}

#[test]
fn prop_bcsr_blocked_matches_naive_baseline() {
    // The register-blocked BCSR kernel and the naive per-block loop visit
    // products in the same order but group sums differently, so they agree
    // to rounding on every shape: empty blocks, generic block heights, tail
    // N-tiles (n % 16 != 0), and single-column B.
    proptest::check(
        "bcsr-blocked-vs-naive",
        25,
        |rng| {
            let bh = 1 + rng.below(8) as usize;
            let bw = 1 + rng.below(4) as usize;
            let m = bh * (1 + rng.below(6) as usize);
            let k = bw * (1 + rng.below(6) as usize);
            let n = 1 + rng.below(40) as usize;
            let density = [0.0f32, 0.2, 0.8][rng.below(3) as usize];
            (bh, bw, m, k, n, density, rng.next_u64())
        },
        |&(bh, bw, m, k, n, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut a = random_sparse(&mut rng, m, k, density);
            clear_row(&mut a, rng.below(m as u32) as usize);
            let t = BcsrTensor::from_dense(&a, bh, bw);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            bcsr_gemm::spmm(&t, &b).allclose(&bcsr_gemm::spmm_naive(&t, &b), TOL, TOL)
        },
    );
}

#[test]
fn prop_nmg_ragged_rows_match_dense() {
    // Row counts deliberately not divisible by m: the final slab is
    // zero-padded and both the blocked and unblocked kernels must still
    // match the densified reference (the row-truncation regression).
    proptest::check(
        "nmg-ragged-vs-dense",
        20,
        |rng| {
            let fmts = [(2usize, 4usize, 4usize), (1, 4, 2), (2, 8, 2)];
            let (nn, m, g) = fmts[rng.below(3) as usize];
            // 1..3m rows, biased to avoid multiples of m.
            let mut rows = 1 + rng.below(3 * m as u32) as usize;
            if rows % m == 0 {
                rows = rows.saturating_sub(1).max(1);
            }
            let k = 1 + rng.below(48) as usize;
            let ncols = 1 + rng.below(32) as usize;
            let density = [0.4f32, 1.0][rng.below(2) as usize];
            (nn, m, g, rows, k, ncols, density, rng.next_u64())
        },
        |&(nn, m, g, rows, k, ncols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut d = random_sparse(&mut rng, rows, k, density);
            d.scale(0.5);
            let a = NmgTensor::from_dense(&d, nn, m, g);
            if a.to_dense().shape() != d.shape() {
                return false; // padding must never change the logical shape
            }
            let mut b = DenseTensor::randn(&[k, ncols], &mut rng);
            b.scale(0.5);
            let want = dense_gemm::matmul_naive(&a.to_dense(), &b);
            nmg_gemm::spmm(&a, &b).allclose(&want, TOL, TOL)
                && nmg_gemm::spmm_unblocked(&a, &b).allclose(&want, TOL, TOL)
        },
    );
}

#[test]
fn all_zero_matrices_multiply_to_zero_everywhere() {
    let mut rng = Pcg64::seeded(99);
    let (m, k, n) = (8, 12, 5);
    let a = DenseTensor::zeros(&[m, k]);
    let b = DenseTensor::randn(&[k, n], &mut rng);
    assert_eq!(dense_gemm::matmul(&a, &b).max_abs(), 0.0);
    assert_eq!(csr_gemm::spmm(&CsrTensor::from_dense(&a), &b).max_abs(), 0.0);
    assert_eq!(ell_gemm::spmm(&EllTensor::from_dense(&a), &b).max_abs(), 0.0);
    assert_eq!(bcsr_gemm::spmm(&BcsrTensor::from_dense(&a, 4, 4), &b).max_abs(), 0.0);
    assert_eq!(nmg_gemm::spmm(&NmgTensor::from_dense(&a, 2, 4, 4), &b).max_abs(), 0.0);
    let w = DenseTensor::zeros(&[k, n]);
    let x = DenseTensor::randn(&[m, k], &mut rng);
    assert_eq!(csc_gemm::spmm_dense_csc(&x, &CscTensor::from_dense(&w)).max_abs(), 0.0);
}
