//! Property-based kernel equivalence: every sparse GEMM matches the naive
//! dense reference on randomized shapes and sparsities, including empty-row
//! and all-zero edge cases, and every SIMD kernel matches its scalar twin
//! on ragged shapes (partial tiles, remainder lanes, rows % m != 0). This
//! is the Nerva lesson (Wesselink et al., 2024): truly-sparse kernels only
//! pay off if they are *exactly* as correct as the dense path they replace.

use sten::formats::{BcsrTensor, CscTensor, CsrTensor, EllTensor, NmgTensor};
use sten::kernels::backend::{self, Backend};
use sten::kernels::{
    bcsr_gemm, csc_gemm, csr_gemm, dense_gemm, elementwise, ell_gemm, nmg_gemm, simd,
};
use sten::tensor::DenseTensor;
use sten::util::proptest;
use sten::util::rng::Pcg64;

// 1e-4, not 1e-5: under the ambient SIMD backend (default auto on AVX2
// hosts) the blocked kernels contract with FMA while the naive references
// stay scalar, which widens the rounding gap slightly.
const TOL: f32 = 1e-4;

/// Random (rows x cols) dense matrix with ~`density` nonzero fraction.
fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f32) -> DenseTensor {
    let data = (0..rows * cols)
        .map(|_| if rng.next_f32() < density { rng.normal() } else { 0.0 })
        .collect();
    DenseTensor::from_vec(&[rows, cols], data)
}

/// Zero out an entire row (the empty-row edge case every row-indexed kernel
/// must survive: empty indptr span, zero ELL occupancy, missing blocks).
fn clear_row(d: &mut DenseTensor, r: usize) {
    let cols = d.cols();
    for c in 0..cols {
        d.set2(r, c, 0.0);
    }
}

fn gen_case(rng: &mut Pcg64) -> (usize, usize, usize, f32, u64) {
    let m = 1 + rng.below(32) as usize;
    let k = 1 + rng.below(48) as usize;
    let n = 1 + rng.below(32) as usize;
    // Sweep the density range including fully-empty matrices.
    let density = [0.0f32, 0.05, 0.3, 0.7, 1.0][rng.below(5) as usize];
    (m, k, n, density, rng.next_u64())
}

#[test]
fn prop_dense_blocked_matches_naive() {
    proptest::check("dense-gemm-vs-naive", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let a = random_sparse(&mut rng, m, k, density);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        dense_gemm::matmul(&a, &b).allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
    });
}

#[test]
fn prop_csr_matches_dense() {
    proptest::check("csr-gemm-vs-dense", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let mut a = random_sparse(&mut rng, m, k, density);
        clear_row(&mut a, rng.below(m as u32) as usize);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        let got = csr_gemm::spmm(&CsrTensor::from_dense(&a), &b);
        got.allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
    });
}

#[test]
fn prop_csc_matches_dense() {
    proptest::check("csc-gemm-vs-dense", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let a = DenseTensor::randn(&[m, k], &mut rng);
        let mut w = random_sparse(&mut rng, k, n, density);
        clear_row(&mut w, rng.below(k as u32) as usize);
        let got = csc_gemm::spmm_dense_csc(&a, &CscTensor::from_dense(&w));
        got.allclose(&dense_gemm::matmul_naive(&a, &w), TOL, TOL)
    });
}

#[test]
fn prop_ell_matches_dense() {
    proptest::check("ell-gemm-vs-dense", 25, gen_case, |&(m, k, n, density, seed)| {
        let mut rng = Pcg64::seeded(seed);
        let mut a = random_sparse(&mut rng, m, k, density);
        // Skew the occupancy: one empty row plus one (possibly) full row.
        clear_row(&mut a, rng.below(m as u32) as usize);
        let b = DenseTensor::randn(&[k, n], &mut rng);
        let got = ell_gemm::spmm(&EllTensor::from_dense(&a), &b);
        got.allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
    });
}

#[test]
fn prop_bcsr_matches_dense() {
    proptest::check(
        "bcsr-gemm-vs-dense",
        25,
        |rng| {
            let bh = 1 + rng.below(4) as usize;
            let bw = 1 + rng.below(4) as usize;
            let m = bh * (1 + rng.below(6) as usize);
            let k = bw * (1 + rng.below(6) as usize);
            let n = 1 + rng.below(24) as usize;
            let density = [0.0f32, 0.2, 0.8][rng.below(3) as usize];
            (bh, bw, m, k, n, density, rng.next_u64())
        },
        |&(bh, bw, m, k, n, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut a = random_sparse(&mut rng, m, k, density);
            clear_row(&mut a, rng.below(m as u32) as usize);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            let got = bcsr_gemm::spmm(&BcsrTensor::from_dense(&a, bh, bw), &b);
            got.allclose(&dense_gemm::matmul_naive(&a, &b), TOL, TOL)
        },
    );
}

#[test]
fn prop_nmg_matches_dense_over_pruned_weights() {
    proptest::check(
        "nmg-gemm-vs-dense",
        20,
        |rng| {
            let fmts = [(2usize, 4usize, 4usize), (1, 4, 2), (2, 8, 2)];
            let (nn, m, g) = fmts[rng.below(3) as usize];
            let slabs = 1 + rng.below(3) as usize;
            let k = 1 + rng.below(48) as usize;
            let ncols = 1 + rng.below(32) as usize;
            let density = [0.0f32, 0.4, 1.0][rng.below(3) as usize];
            (nn, m, g, slabs, k, ncols, density, rng.next_u64())
        },
        |&(nn, m, g, slabs, k, ncols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut d = random_sparse(&mut rng, slabs * m, k, density);
            clear_row(&mut d, rng.below((slabs * m) as u32) as usize);
            // The n:m:g kernel reorders columns by pattern, so unlike the
            // row-ordered kernels its summation order genuinely differs from
            // the reference; halve the operand scale to keep accumulated
            // rounding far inside the 1e-5 window.
            d.scale(0.5);
            let a = NmgTensor::from_dense(&d, nn, m, g);
            let mut b = DenseTensor::randn(&[k, ncols], &mut rng);
            b.scale(0.5);
            // The kernel must match the dense GEMM over the *pruned* matrix.
            let got = nmg_gemm::spmm(&a, &b);
            got.allclose(&dense_gemm::matmul_naive(&a.to_dense(), &b), TOL, TOL)
        },
    );
}

#[test]
fn prop_bcsr_blocked_matches_naive_baseline() {
    // The register-blocked BCSR kernel and the naive per-block loop visit
    // products in the same order but group sums differently, so they agree
    // to rounding on every shape: empty blocks, generic block heights, tail
    // N-tiles (n % 16 != 0), and single-column B.
    proptest::check(
        "bcsr-blocked-vs-naive",
        25,
        |rng| {
            let bh = 1 + rng.below(8) as usize;
            let bw = 1 + rng.below(4) as usize;
            let m = bh * (1 + rng.below(6) as usize);
            let k = bw * (1 + rng.below(6) as usize);
            let n = 1 + rng.below(40) as usize;
            let density = [0.0f32, 0.2, 0.8][rng.below(3) as usize];
            (bh, bw, m, k, n, density, rng.next_u64())
        },
        |&(bh, bw, m, k, n, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut a = random_sparse(&mut rng, m, k, density);
            clear_row(&mut a, rng.below(m as u32) as usize);
            let t = BcsrTensor::from_dense(&a, bh, bw);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            bcsr_gemm::spmm(&t, &b).allclose(&bcsr_gemm::spmm_naive(&t, &b), TOL, TOL)
        },
    );
}

#[test]
fn prop_nmg_ragged_rows_match_dense() {
    // Row counts deliberately not divisible by m: the final slab is
    // zero-padded and both the blocked and unblocked kernels must still
    // match the densified reference (the row-truncation regression).
    proptest::check(
        "nmg-ragged-vs-dense",
        20,
        |rng| {
            let fmts = [(2usize, 4usize, 4usize), (1, 4, 2), (2, 8, 2)];
            let (nn, m, g) = fmts[rng.below(3) as usize];
            // 1..3m rows, biased to avoid multiples of m.
            let mut rows = 1 + rng.below(3 * m as u32) as usize;
            if rows % m == 0 {
                rows = rows.saturating_sub(1).max(1);
            }
            let k = 1 + rng.below(48) as usize;
            let ncols = 1 + rng.below(32) as usize;
            let density = [0.4f32, 1.0][rng.below(2) as usize];
            (nn, m, g, rows, k, ncols, density, rng.next_u64())
        },
        |&(nn, m, g, rows, k, ncols, density, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut d = random_sparse(&mut rng, rows, k, density);
            d.scale(0.5);
            let a = NmgTensor::from_dense(&d, nn, m, g);
            if a.to_dense().shape() != d.shape() {
                return false; // padding must never change the logical shape
            }
            let mut b = DenseTensor::randn(&[k, ncols], &mut rng);
            b.scale(0.5);
            let want = dense_gemm::matmul_naive(&a.to_dense(), &b);
            nmg_gemm::spmm(&a, &b).allclose(&want, TOL, TOL)
                && nmg_gemm::spmm_unblocked(&a, &b).allclose(&want, TOL, TOL)
        },
    );
}

/// Run `f` under a forced backend (guard held for the duration). Backend
/// forcing is allowed here because this is an integration binary: the force
/// guards serialize on a process-global lock, and every comparison in this
/// file tolerates either ambient backend.
fn with_backend<T>(b: Backend, f: impl FnOnce() -> T) -> T {
    let _g = backend::force(b);
    f()
}

#[test]
fn prop_simd_dense_matches_scalar_on_ragged_shapes() {
    if !simd::have_avx2_fma() {
        eprintln!("skipping SIMD-vs-scalar dense property: no AVX2+FMA");
        return;
    }
    proptest::check(
        "simd-dense-vs-scalar",
        20,
        |rng| {
            let m = 1 + rng.below(40) as usize; // rows % MR free to be ragged
            let k = 1 + rng.below(64) as usize;
            // Bias N toward remainder lanes: tail widths 1..15 (below one
            // mask width and between the two halves) plus exact multiples.
            let n = 16 * rng.below(3) as usize + 1 + rng.below(15) as usize;
            (m, k, n, rng.next_u64())
        },
        |&(m, k, n, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let a = DenseTensor::randn(&[m, k], &mut rng);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            let s = with_backend(Backend::Scalar, || dense_gemm::matmul(&a, &b));
            let v = with_backend(Backend::Simd, || dense_gemm::matmul(&a, &b));
            s.allclose(&v, TOL, TOL)
        },
    );
}

#[test]
fn prop_simd_nmg_matches_scalar_on_ragged_shapes() {
    if !simd::have_avx2_fma() {
        eprintln!("skipping SIMD-vs-scalar nmg property: no AVX2+FMA");
        return;
    }
    proptest::check(
        "simd-nmg-vs-scalar",
        20,
        |rng| {
            let fmts = [(2usize, 4usize, 4usize), (1, 4, 2), (2, 8, 2)];
            let (nn, m, g) = fmts[rng.below(3) as usize];
            // Ragged rows (rows % m != 0 whenever possible) and ragged K so
            // the final chunk carries pad slots.
            let mut rows = 1 + rng.below(3 * m as u32) as usize;
            if rows % m == 0 {
                rows = rows.saturating_sub(1).max(1);
            }
            let k = 1 + rng.below(64) as usize;
            let ncols = 1 + rng.below(48) as usize;
            (nn, m, g, rows, k, ncols, rng.next_u64())
        },
        |&(nn, m, g, rows, k, ncols, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let d = random_sparse(&mut rng, rows, k, 0.7);
            let a = NmgTensor::from_dense(&d, nn, m, g);
            let b = DenseTensor::randn(&[k, ncols], &mut rng);
            let s = with_backend(Backend::Scalar, || nmg_gemm::spmm(&a, &b));
            let v = with_backend(Backend::Simd, || nmg_gemm::spmm(&a, &b));
            s.allclose(&v, TOL, TOL)
        },
    );
}

#[test]
fn prop_simd_bcsr_matches_scalar_on_partial_blocks() {
    if !simd::have_avx2_fma() {
        eprintln!("skipping SIMD-vs-scalar bcsr property: no AVX2+FMA");
        return;
    }
    proptest::check(
        "simd-bcsr-vs-scalar",
        20,
        |rng| {
            // Specialized heights (2/4/8 take the SIMD path on full tiles)
            // plus a generic one (3) that must stay on the scalar kernel.
            let bh = [2usize, 4, 8, 3][rng.below(4) as usize];
            let bw = 1 + rng.below(8) as usize;
            let m = bh * (1 + rng.below(5) as usize);
            let k = bw * (1 + rng.below(5) as usize);
            let n = 1 + rng.below(40) as usize; // tail tiles n % 16 != 0
            (bh, bw, m, k, n, rng.next_u64())
        },
        |&(bh, bw, m, k, n, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut a = random_sparse(&mut rng, m, k, 0.5);
            clear_row(&mut a, rng.below(m as u32) as usize);
            let t = BcsrTensor::from_dense(&a, bh, bw);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            let s = with_backend(Backend::Scalar, || bcsr_gemm::spmm(&t, &b));
            let v = with_backend(Backend::Simd, || bcsr_gemm::spmm(&t, &b));
            s.allclose(&v, TOL, TOL)
        },
    );
}

#[test]
fn prop_simd_row_kernels_match_scalar() {
    if !simd::have_avx2_fma() {
        eprintln!("skipping SIMD-vs-scalar row-kernel property: no AVX2+FMA");
        return;
    }
    proptest::check(
        "simd-rows-vs-scalar",
        20,
        |rng| {
            let r = 1 + rng.below(12) as usize;
            // Widths straddling the vector width: < 8 (scalar fallback),
            // exactly 8, and ragged remainders.
            let c = 1 + rng.below(40) as usize;
            (r, c, rng.next_u64())
        },
        |&(r, c, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let x = DenseTensor::randn(&[r, c], &mut rng);
            let gamma: Vec<f32> = (0..c).map(|_| 0.5 + rng.next_f32()).collect();
            let beta: Vec<f32> = (0..c).map(|_| rng.next_f32() - 0.5).collect();
            let (s_sm, s_ln, s_ba) = with_backend(Backend::Scalar, || {
                (
                    elementwise::softmax_rows(&x),
                    elementwise::layernorm_rows(&x, &gamma, &beta),
                    elementwise::bias_add(&x, &beta),
                )
            });
            let (v_sm, v_ln, v_ba) = with_backend(Backend::Simd, || {
                (
                    elementwise::softmax_rows(&x),
                    elementwise::layernorm_rows(&x, &gamma, &beta),
                    elementwise::bias_add(&x, &beta),
                )
            });
            // Softmax and bias_add are bit-identical seams; layernorm
            // reassociates its mean/variance sums, so allclose.
            s_sm.data() == v_sm.data()
                && s_ba.data() == v_ba.data()
                && s_ln.allclose(&v_ln, TOL, TOL)
        },
    );
}

#[test]
fn force_scalar_env_masks_feature_detection() {
    // The pure resolution table: a masked or unsupported host must degrade
    // to scalar no matter what the request says.
    assert_eq!(backend::resolve_request(None, true, true), Backend::Scalar);
    assert_eq!(backend::resolve_request(Some("simd"), true, true), Backend::Scalar);
    assert_eq!(backend::resolve_request(Some("auto"), false, false), Backend::Scalar);
    assert_eq!(backend::resolve_request(Some("simd"), false, false), Backend::Scalar);

    // Env-driven: STEN_FORCE_SCALAR=1 masks AVX2 even when detected. No
    // other test in this binary reads these variables, so the set/remove
    // window cannot race a concurrent resolution.
    std::env::set_var("STEN_FORCE_SCALAR", "1");
    assert_eq!(backend::resolve_env(), Backend::Scalar);
    std::env::remove_var("STEN_FORCE_SCALAR");
    // With the mask gone, resolution follows the ambient request + the
    // host's real feature detection.
    let req = std::env::var("STEN_BACKEND").ok();
    let expect = backend::resolve_request(req.as_deref(), false, simd::have_avx2_fma());
    assert_eq!(backend::resolve_env(), expect);
}

#[test]
fn all_zero_matrices_multiply_to_zero_everywhere() {
    let mut rng = Pcg64::seeded(99);
    let (m, k, n) = (8, 12, 5);
    let a = DenseTensor::zeros(&[m, k]);
    let b = DenseTensor::randn(&[k, n], &mut rng);
    assert_eq!(dense_gemm::matmul(&a, &b).max_abs(), 0.0);
    assert_eq!(csr_gemm::spmm(&CsrTensor::from_dense(&a), &b).max_abs(), 0.0);
    assert_eq!(ell_gemm::spmm(&EllTensor::from_dense(&a), &b).max_abs(), 0.0);
    assert_eq!(bcsr_gemm::spmm(&BcsrTensor::from_dense(&a, 4, 4), &b).max_abs(), 0.0);
    assert_eq!(nmg_gemm::spmm(&NmgTensor::from_dense(&a, 2, 4, 4), &b).max_abs(), 0.0);
    let w = DenseTensor::zeros(&[k, n]);
    let x = DenseTensor::randn(&[m, k], &mut rng);
    assert_eq!(csc_gemm::spmm_dense_csc(&x, &CscTensor::from_dense(&w)).max_abs(), 0.0);
}
