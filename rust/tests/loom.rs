//! Exhaustive-interleaving suite for the hand-rolled sync primitives.
//!
//! Compiled only under `--features loom`, which routes the `util::sync`
//! shim to the in-tree model checker (`util::loom`): every `Mutex`,
//! `Condvar`, atomic, and spawned thread below becomes a scheduling point,
//! and each `check` call replays its body under every interleaving the
//! stated bounds permit (CHESS-style preemption bounding plus a budget of
//! injected condvar timeouts). A test passes only if the invariant holds
//! on *every* explored schedule; failures print the decision path.
//!
//! Run locally with:
//!
//! ```text
//! cargo test --features loom --test loom
//! ```
//!
//! The bounds keep each test to a few thousand schedules so the suite
//! stays in CI budgets; `util::loom` prints a coverage-truncated notice if
//! a cap is ever the binding constraint.

#![cfg(feature = "loom")]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use sten::coordinator::CompletionLatch;
use sten::dist::ShardBarrier;
use sten::util::channel::{bounded, Received, TrySendError};
use sten::util::loom::ModelOptions;
use sten::util::sync::atomic::{AtomicUsize, Ordering};
use sten::util::sync::{thread, Arc, Mutex};
use sten::util::ThreadPool;

/// Bounds for the threadpool models: they involve three-plus threads and a
/// few hundred scheduling points per execution, so one preemption and one
/// optional timeout per schedule keeps the space tractable.
fn pool_bounds() -> ModelOptions {
    ModelOptions {
        preemption_bound: Some(1),
        timeout_budget: 1,
        max_iterations: 1500,
        time_budget: Some(Duration::from_secs(15)),
        ..ModelOptions::default()
    }
}

/// Bounds for the smaller channel / latch models.
fn channel_bounds() -> ModelOptions {
    ModelOptions {
        preemption_bound: Some(2),
        timeout_budget: 2,
        max_iterations: 4000,
        time_budget: Some(Duration::from_secs(10)),
        ..ModelOptions::default()
    }
}

/// A deadline far enough out that it can only fire as a model-injected
/// timeout, never as a wall-clock one.
fn far_deadline() -> Instant {
    Instant::now() + Duration::from_secs(3600)
}

// ---------------------------------------------------------------------------
// ThreadPool: ticket steal vs cursor exhaustion, nesting, panic poisoning.
// ---------------------------------------------------------------------------

/// Every index of a scope is executed exactly once, whether the stealable
/// ticket is claimed by a worker, raced by both workers, or left stale
/// because the owner's cursor loop exhausted the range first.
#[test]
fn pool_scope_runs_every_chunk_exactly_once() {
    pool_bounds().check(|| {
        let pool = ThreadPool::new(2);
        let seen = Arc::new(Mutex::new(Vec::new()));
        {
            let seen = Arc::clone(&seen);
            pool.scope_chunks(4, 1, move |start, end| {
                for i in start..end {
                    seen.lock().unwrap().push(i);
                }
            });
        }
        let mut got = Arc::try_unwrap(seen)
            .ok()
            .expect("scope closure dropped")
            .into_inner()
            .unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3], "lost or duplicated chunk");
    });
}

/// A scope body may open a nested scope on the same pool; the outer owner
/// drives its remaining chunks to completion even while workers are parked
/// inside the inner scope's wait.
#[test]
fn pool_nested_scope_completes() {
    pool_bounds().check(|| {
        let pool = ThreadPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        {
            let pool_ref = &pool;
            let hits = Arc::clone(&hits);
            pool.scope_chunks(2, 1, move |s, e| {
                for _ in s..e {
                    let hits = Arc::clone(&hits);
                    pool_ref.scope_chunks(2, 1, move |is, ie| {
                        hits.fetch_add(ie - is, Ordering::SeqCst);
                    });
                }
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 4, "nested scope lost chunks");
    });
}

/// A panicking chunk poisons the job — the owner re-raises the original
/// payload — but the workers survive and the pool keeps serving scopes.
#[test]
fn pool_scope_panic_poisons_job_but_not_workers() {
    pool_bounds().check(|| {
        let pool = ThreadPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_chunks(2, 1, |start, _end| {
                if start == 0 {
                    panic!("boom");
                }
            });
        }));
        let payload = result.expect_err("scope owner must re-raise the chunk panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom", "original panic payload must survive the pool");
        // The pool is still functional: a fresh scope completes on the same
        // workers that just caught the poisoned job.
        let hits = Arc::new(AtomicUsize::new(0));
        {
            let hits = Arc::clone(&hits);
            pool.scope_chunks(2, 1, move |s, e| {
                hits.fetch_add(e - s, Ordering::SeqCst);
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 2, "pool dead after poisoned scope");
    });
}

/// Dropping the pool always terminates and joins both workers, in every
/// interleaving of the shutdown flag, the wake epoch, and worker parking.
#[test]
fn pool_drop_joins_workers() {
    pool_bounds().check(|| {
        let pool = ThreadPool::new(2);
        drop(pool);
    });
}

// ---------------------------------------------------------------------------
// Channel: deadline recv vs send, close vs parked receivers, exactly-once.
// ---------------------------------------------------------------------------

/// A deadline recv racing a send may time out (the model can fire the
/// timeout before the send lands), but it must never *lose* the item: if
/// the send already enqueued, the timed-out wake delivers it; otherwise a
/// follow-up recv does.
#[test]
fn channel_deadline_recv_never_loses_racing_send() {
    channel_bounds().check(|| {
        let (tx, rx) = bounded::<u32>(1);
        let sender = thread::spawn(move || {
            tx.send(7).unwrap();
            // tx drops here: the channel closes once the item is consumed.
        });
        match rx.recv_deadline(far_deadline()) {
            Received::Item(v) => assert_eq!(v, 7),
            Received::TimedOut => {
                // The model fired the timeout before the send enqueued; the
                // item must still be consumable afterwards.
                assert_eq!(rx.recv(), Some(7), "racing send lost its item");
            }
            Received::Closed => panic!("channel closed while an item was in flight"),
        }
        sender.join().unwrap();
        assert_eq!(rx.recv(), None, "channel must report closed after drain");
    });
}

/// Closing the channel (last sender drops) wakes every parked receiver; no
/// receiver sleeps through the close or reports anything but `None`.
#[test]
fn channel_close_wakes_parked_receivers() {
    channel_bounds().check(|| {
        let (tx, rx) = bounded::<u32>(1);
        let receivers: Vec<_> = (0..2)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || rx.recv())
            })
            .collect();
        drop(rx);
        drop(tx); // receivers may already be parked, or not yet started
        for handle in receivers {
            assert_eq!(handle.join().unwrap(), None, "receiver missed the close");
        }
    });
}

/// Two receivers competing for one item: exactly one gets it, the other
/// observes closure — never both, never neither.
#[test]
fn channel_two_receivers_deliver_exactly_once() {
    channel_bounds().check(|| {
        let (tx, rx) = bounded::<u32>(1);
        let receivers: Vec<_> = (0..2)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || rx.recv())
            })
            .collect();
        drop(rx);
        tx.send(9).unwrap();
        drop(tx);
        let outcomes: Vec<_> =
            receivers.into_iter().map(|h| h.join().unwrap()).collect();
        let delivered = outcomes.iter().filter(|o| **o == Some(9)).count();
        let closed = outcomes.iter().filter(|o| o.is_none()).count();
        assert_eq!(
            (delivered, closed),
            (1, 1),
            "item must be delivered exactly once, got {outcomes:?}"
        );
    });
}

/// Backpressure: a sender parked on a full queue is woken by the consuming
/// recv and FIFO order is preserved across the park.
#[test]
fn channel_full_queue_send_parks_until_recv() {
    channel_bounds().check(|| {
        let (tx, rx) = bounded::<u32>(1);
        let sender = thread::spawn(move || {
            tx.send(1).unwrap();
            tx.send(2).unwrap(); // parks whenever the first item is still queued
        });
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        sender.join().unwrap();
        assert_eq!(rx.recv(), None);
    });
}

/// `try_send` (the non-blocking submit path) never blocks, never loses an
/// item and never duplicates one: on success the item is delivered exactly
/// once; on `Full` the value is handed back and must never surface at the
/// receiver. The sender's return and the consumer's observations have to
/// agree in every interleaving with a racing recv.
#[test]
fn channel_try_send_never_blocks_or_duplicates() {
    channel_bounds().check(|| {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap(); // the queue starts full
        let consumer = thread::spawn(move || {
            let first = rx.recv();
            let second = rx.recv();
            (first, second)
        });
        let attempt = tx.try_send(2); // races the consumer's first recv
        drop(tx);
        let (first, second) = consumer.join().unwrap();
        assert_eq!(first, Some(1), "pre-filled item lost");
        match attempt {
            Ok(()) => assert_eq!(second, Some(2), "accepted item never delivered"),
            Err(TrySendError::Full(v)) => {
                assert_eq!(v, 2, "rejected item not handed back intact");
                assert_eq!(second, None, "rejected item must not be delivered");
            }
            Err(TrySendError::Closed(_)) => {
                panic!("channel reported closed while the receiver was alive")
            }
        }
    });
}

// ---------------------------------------------------------------------------
// CompletionLatch: the serving drain() rendezvous.
// ---------------------------------------------------------------------------

/// `wait(target)` racing the final `account` never sleeps through the
/// wakeup, whether the accounts land before the wait starts, between its
/// check and its park, or after it parks.
#[test]
fn latch_wait_never_misses_final_account() {
    channel_bounds().check(|| {
        let latch = Arc::new(CompletionLatch::new());
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let latch = Arc::clone(&latch);
                thread::spawn(move || latch.account(1))
            })
            .collect();
        latch.wait(2);
        assert_eq!(latch.count(), 2);
        for w in workers {
            w.join().unwrap();
        }
    });
}

// ---------------------------------------------------------------------------
// ShardBarrier: the per-step rendezvous of the ring collectives.
// ---------------------------------------------------------------------------

/// Every party's pre-barrier write is visible to every party after `wait`
/// returns, and the sense-reversing generation makes the barrier reusable:
/// a second round on the same barrier never deadlocks and never releases a
/// party early, in any interleaving of arrivals, wakeups and the
/// generation flip.
#[test]
fn shard_barrier_releases_all_parties_with_writes_visible() {
    channel_bounds().check(|| {
        let barrier = Arc::new(ShardBarrier::new(2));
        let hits = Arc::new(AtomicUsize::new(0));
        let peer = {
            let barrier = Arc::clone(&barrier);
            let hits = Arc::clone(&hits);
            thread::spawn(move || {
                hits.fetch_add(1, Ordering::SeqCst);
                barrier.wait();
                assert_eq!(hits.load(Ordering::SeqCst), 2, "peer write invisible");
                barrier.wait(); // round 2: the generation flip must reopen it
            })
        };
        hits.fetch_add(1, Ordering::SeqCst);
        barrier.wait();
        assert_eq!(hits.load(Ordering::SeqCst), 2, "peer write invisible");
        barrier.wait();
        peer.join().unwrap();
    });
}

/// The collective publish protocol: each rank writes its slot, crosses the
/// barrier, then reads its neighbor's slot. The barrier must order every
/// publish before every cross-rank read — the happens-before edge the
/// `ShardGroup` ring steps rely on for their raw-pointer exchanges.
#[test]
fn shard_barrier_orders_slot_publish_before_neighbor_read() {
    channel_bounds().check(|| {
        let slots = Arc::new(vec![Mutex::new(0usize), Mutex::new(0usize)]);
        let barrier = Arc::new(ShardBarrier::new(2));
        let handles: Vec<_> = (0..2)
            .map(|rank: usize| {
                let slots = Arc::clone(&slots);
                let barrier = Arc::clone(&barrier);
                thread::spawn(move || {
                    *slots[rank].lock().unwrap() = rank + 1;
                    barrier.wait();
                    let neighbor = (rank + 1) % 2;
                    let got = *slots[neighbor].lock().unwrap();
                    assert_eq!(got, neighbor + 1, "neighbor publish not ordered before read");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}
