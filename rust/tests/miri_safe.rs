//! Miri-sized exercise of the crate's unsafe disjoint-write paths.
//!
//! This file is the `cargo miri test --test miri_safe` lane: every test
//! routes through at least one `unsafe` block — the `SyncPtr` output
//! writes in `kernels/` and `tensor/dense.rs`, the lifetime-erased scope
//! closures in `util/threadpool.rs` — with shapes small enough that the
//! interpreter finishes in seconds. Miri needs
//! `MIRIFLAGS="-Zmiri-disable-isolation"` because the threadpool's parked
//! workers read the clock (`Condvar::wait_timeout`).
//!
//! The same tests run under plain `cargo test` too (they are ordinary
//! correctness checks, just tiny), so the subset can never drift from the
//! real kernels.

use sten::formats::{
    convert, AnyTensor, BcsrTensor, CscTensor, CsrTensor, EllTensor, Layout, NmgTensor,
};
use sten::kernels::{bcsr_gemm, csc_gemm, csr_gemm, dense_gemm, elementwise, ell_gemm, nmg_gemm};
use sten::tensor::DenseTensor;
use sten::util::{Pcg64, ThreadPool};

/// A small random matrix with roughly half its entries forced to zero, so
/// the sparse formats have real structure to compress.
fn sparse_randn(rows: usize, cols: usize, seed: u64) -> DenseTensor {
    let mut rng = Pcg64::seeded(seed);
    DenseTensor::randn(&[rows, cols], &mut rng).map(|v| if v.abs() < 0.7 { 0.0 } else { v })
}

fn dense_randn(rows: usize, cols: usize, seed: u64) -> DenseTensor {
    let mut rng = Pcg64::seeded(seed);
    DenseTensor::randn(&[rows, cols], &mut rng)
}

#[test]
fn csr_spmm_matches_naive() {
    let a = sparse_randn(8, 6, 1);
    let b = dense_randn(6, 5, 2);
    let got = csr_gemm::spmm(&CsrTensor::from_dense(&a), &b);
    let want = dense_gemm::matmul_naive(&a, &b);
    assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
}

#[test]
fn csc_spmm_matches_naive() {
    let a = dense_randn(5, 6, 3);
    let b = sparse_randn(6, 4, 4);
    let got = csc_gemm::spmm_dense_csc(&a, &CscTensor::from_dense(&b));
    let want = dense_gemm::matmul_naive(&a, &b);
    assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
}

#[test]
fn ell_spmm_matches_naive() {
    let a = sparse_randn(7, 6, 5);
    let b = dense_randn(6, 3, 6);
    let got = ell_gemm::spmm(&EllTensor::from_dense(&a), &b);
    let want = dense_gemm::matmul_naive(&a, &b);
    assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
}

#[test]
fn bcsr_spmm_matches_naive() {
    let a = sparse_randn(8, 6, 7);
    let b = dense_randn(6, 5, 8);
    let got = bcsr_gemm::spmm(&BcsrTensor::from_dense(&a, 2, 2), &b);
    let want = dense_gemm::matmul_naive(&a, &b);
    assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
}

#[test]
fn nmg_spmm_matches_naive() {
    let dense = dense_randn(8, 16, 9);
    for a in [NmgTensor::from_dense(&dense, 2, 4, 2), NmgTensor::from_dense_swap(&dense, 2, 4, 2)] {
        let b = dense_randn(16, 5, 10);
        let got = nmg_gemm::spmm(&a, &b);
        let want = dense_gemm::matmul_naive(&a.to_dense(), &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }
}

#[test]
fn blocked_dense_gemm_matches_naive() {
    // Odd shapes hit the partial-panel tails of the blocked kernel.
    let a = dense_randn(9, 7, 11);
    let b = dense_randn(7, 5, 12);
    let got = dense_gemm::matmul(&a, &b);
    let want = dense_gemm::matmul_naive(&a, &b);
    assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
}

#[test]
fn masked_gemm_matches_naive() {
    let a = dense_randn(6, 6, 13);
    let mask = sparse_randn(6, 6, 14).map(|v| if v != 0.0 { 1.0 } else { 0.0 });
    let b = dense_randn(6, 4, 15);
    let got = dense_gemm::matmul_masked(&a, &mask, &b);
    let want = dense_gemm::matmul_naive(&a.zip(&mask, |x, m| x * m), &b);
    assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
}

#[test]
fn lossless_conversions_roundtrip() {
    let original = sparse_randn(6, 8, 16);
    let src = AnyTensor::Dense(original.clone());
    for target in [Layout::Csr, Layout::Csc, Layout::Coo, Layout::Ell, Layout::Masked] {
        let converted = convert::lossless(&src, target)
            .unwrap_or_else(|| panic!("dense -> {target:?} must be lossless"));
        assert_eq!(converted.layout(), target);
        assert!(
            converted.to_dense().allclose(&original, 0.0, 0.0),
            "{target:?} roundtrip lost values"
        );
    }
    // Structured formats escape losslessly to exact formats.
    let nmg = AnyTensor::Nmg(NmgTensor::from_dense(&dense_randn(8, 16, 17), 2, 4, 2));
    let escaped = convert::lossless(&nmg, Layout::Csr).expect("nmg -> csr escape");
    assert!(escaped.to_dense().allclose(&nmg.to_dense(), 0.0, 0.0));
    // But never back *into* a structured format.
    assert!(convert::lossless(&src, Layout::Nmg).is_none());
}

#[test]
fn explicit_bcsr_conversion_roundtrips() {
    let original = sparse_randn(8, 8, 18);
    let b = convert::to_bcsr(&AnyTensor::Dense(original.clone()), 4, 4);
    assert_eq!(b.layout(), Layout::Bcsr);
    assert!(b.to_dense().allclose(&original, 0.0, 0.0));
}

#[test]
fn transpose2_involution() {
    // `transpose2` writes its output rows through a `SyncPtr`.
    let x = dense_randn(9, 5, 19);
    let t = x.transpose2();
    assert_eq!(t.shape(), &[5usize, 9][..]);
    assert!(t.transpose2().allclose(&x, 0.0, 0.0));
}

#[test]
fn elementwise_kernels_small() {
    let x = dense_randn(4, 6, 20);
    let r = elementwise::relu(&x);
    assert!(r.data().iter().all(|&v| v >= 0.0));
    let g = elementwise::gelu(&x);
    assert!(g.data().iter().all(|v| v.is_finite()));
    let s = elementwise::softmax_rows(&x);
    for i in 0..4 {
        let row_sum: f32 = s.data()[i * 6..(i + 1) * 6].iter().sum();
        assert!((row_sum - 1.0).abs() < 1e-5, "softmax row {i} sums to {row_sum}");
    }
    let gamma = vec![1.0f32; 6];
    let beta = vec![0.5f32; 6];
    let ln = elementwise::layernorm_rows(&x, &gamma, &beta);
    assert!(ln.data().iter().all(|v| v.is_finite()));
    let biased = elementwise::bias_add(&x, &beta);
    assert!((biased.data()[0] - (x.data()[0] + 0.5)).abs() < 1e-6);
}

#[test]
fn scoped_pool_covers_every_index_once() {
    // The lifetime-erased `RawTask` path with a pool small enough for Miri.
    let pool = ThreadPool::new(2);
    let hits: Vec<std::sync::atomic::AtomicU32> =
        (0..16).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
    pool.scope_chunks(16, 3, |start, end| {
        for i in start..end {
            hits[i].fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        }
    });
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(std::sync::atomic::Ordering::SeqCst), 1, "index {i}");
    }
    let squares = pool.map(8, |i| i * i);
    assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
}
