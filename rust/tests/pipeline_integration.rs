//! Pipeline integration: golden-vector verification of the AOT path.
//!
//! `aot.py` records, for selected artifacts, deterministic inputs and the
//! outputs jax computed for them (`*.golden.bin`). These tests replay the
//! inputs through the Rust PJRT runtime and require numeric agreement — a
//! true cross-language check that catches HLO-translation bugs (e.g. the
//! non-leading-batch-dim dot miscompilation found during development).
//!
//! The check no longer skips when `make artifacts` has not run: without a
//! jax-produced golden, `sten::parity::ensure_golden` generates one
//! hermetically from the forced-scalar reference backend into
//! `target/goldens`, so the golden path always executes. Tolerances come
//! from the per-seam table in `sten::parity::SEAMS` (same bounds this file
//! historically hard-coded).

use sten::parity;
use sten::runtime::ArtifactRuntime;

fn runtime() -> ArtifactRuntime {
    ArtifactRuntime::open_default().expect("artifact runtime")
}

fn check_golden(name: &str) {
    let rt = runtime();
    let path = parity::ensure_golden(&rt, name)
        .unwrap_or_else(|e| panic!("golden for {name}: {e}"));
    let (inputs, want) = parity::load_golden(&rt, name, &path).unwrap();
    let got = rt.call(name, &inputs).unwrap();
    assert_eq!(got.len(), want.len());
    let seam = parity::seam_for(name);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let g = g.as_f32().unwrap();
        assert!(
            g.allclose(w, seam.rtol, seam.atol),
            "{name} output {i}: max diff {}",
            g.max_abs_diff(w)
        );
    }
}

#[test]
fn golden_gemm_dense() {
    check_golden("gemm_dense_8x48x16");
}

#[test]
fn golden_gemm_dense_large() {
    check_golden("gemm_dense_64x192x128");
}

#[test]
fn golden_gemm_masked() {
    check_golden("gemm_masked_8x48x16");
}

#[test]
fn golden_gemm_masked_large() {
    check_golden("gemm_masked_64x192x128");
}

#[test]
fn golden_gemm_nmg() {
    check_golden("gemm_nmg_8x48x16");
}

#[test]
fn golden_gemm_nmg_large() {
    check_golden("gemm_nmg_16x96x64");
}

#[test]
fn golden_attn_block() {
    check_golden("attn_block_tiny");
}

#[test]
fn golden_ffn_block() {
    check_golden("ffn_block_tiny");
}

#[test]
fn golden_ffn_block_nmg() {
    check_golden("ffn_block_nmg_tiny");
}

#[test]
fn golden_encoder_fwd() {
    check_golden("encoder_fwd_tiny");
}

#[test]
fn golden_embed() {
    check_golden("embed_tiny");
}

#[test]
fn golden_lm_head() {
    check_golden("lm_head_tiny");
}
