//! Pipeline integration: golden-vector verification of the AOT path.
//!
//! `aot.py` records, for selected artifacts, deterministic inputs and the
//! outputs jax computed for them (`*.golden.bin`). These tests replay the
//! inputs through the Rust PJRT runtime and require numeric agreement — a
//! true cross-language check that catches HLO-translation bugs (e.g. the
//! non-leading-batch-dim dot miscompilation found during development).

use sten::runtime::{ArtifactRuntime, Value};
use sten::tensor::DenseTensor;

fn runtime() -> ArtifactRuntime {
    ArtifactRuntime::open_default().expect("artifact runtime")
}

/// Load a golden file: inputs then outputs, in manifest order, little-endian.
fn load_golden(rt: &ArtifactRuntime, name: &str) -> (Vec<Value>, Vec<DenseTensor>) {
    let spec = rt.spec(name).unwrap().clone();
    let dir = sten::runtime::default_artifacts_dir();
    let bytes = std::fs::read(dir.join(format!("{name}.golden.bin")))
        .unwrap_or_else(|e| panic!("missing golden for {name}: {e}"));
    let mut off = 0usize;
    let mut take = |n: usize| -> &[u8] {
        let s = &bytes[off..off + 4 * n];
        off += 4 * n;
        s
    };
    let mut inputs = Vec::new();
    for io in &spec.inputs {
        let raw = take(io.numel());
        match io.dtype {
            sten::runtime::DType::F32 => {
                let f: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                inputs.push(Value::from(DenseTensor::from_vec(&io.shape, f)));
            }
            sten::runtime::DType::I32 => {
                let ints: Vec<i32> = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                inputs.push(Value::I32(io.shape.clone(), ints));
            }
        }
    }
    let mut outputs = Vec::new();
    for io in &spec.outputs {
        let raw = take(io.numel());
        let f: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        outputs.push(DenseTensor::from_vec(&io.shape, f));
    }
    assert_eq!(off, bytes.len(), "golden length mismatch for {name}");
    (inputs, outputs)
}

fn check_golden(name: &str, rtol: f32, atol: f32) {
    // Golden vectors are produced by jax in `make artifacts`; without them
    // (offline builds run on the native backend's built-in manifest) the
    // cross-language check has nothing to compare against — skip, loudly.
    let dir = sten::runtime::default_artifacts_dir();
    if !dir.join(format!("{name}.golden.bin")).is_file() {
        eprintln!("skipping golden check for {name}: no golden vector (run `make artifacts`)");
        return;
    }
    let rt = runtime();
    let (inputs, want) = load_golden(&rt, name);
    let got = rt.call(name, &inputs).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let g = g.as_f32().unwrap();
        assert!(
            g.allclose(w, rtol, atol),
            "{name} output {i}: max diff {}",
            g.max_abs_diff(w)
        );
    }
}

#[test]
fn golden_gemm_dense() {
    check_golden("gemm_dense_8x48x16", 1e-4, 1e-4);
}

#[test]
fn golden_gemm_dense_large() {
    check_golden("gemm_dense_64x192x128", 1e-4, 1e-4);
}

#[test]
fn golden_gemm_masked() {
    check_golden("gemm_masked_8x48x16", 1e-4, 1e-4);
}

#[test]
fn golden_gemm_masked_large() {
    check_golden("gemm_masked_64x192x128", 1e-4, 1e-4);
}

#[test]
fn golden_gemm_nmg() {
    check_golden("gemm_nmg_8x48x16", 1e-4, 1e-4);
}

#[test]
fn golden_gemm_nmg_large() {
    check_golden("gemm_nmg_16x96x64", 1e-4, 1e-4);
}

#[test]
fn golden_attn_block() {
    check_golden("attn_block_tiny", 1e-3, 1e-3);
}

#[test]
fn golden_ffn_block() {
    check_golden("ffn_block_tiny", 1e-3, 1e-3);
}

#[test]
fn golden_ffn_block_nmg() {
    check_golden("ffn_block_nmg_tiny", 1e-3, 1e-3);
}

#[test]
fn golden_encoder_fwd() {
    check_golden("encoder_fwd_tiny", 1e-2, 1e-2);
}

#[test]
fn golden_embed() {
    check_golden("embed_tiny", 1e-5, 1e-5);
}

#[test]
fn golden_lm_head() {
    check_golden("lm_head_tiny", 1e-3, 1e-3);
}
