//! Integration: the Rust runtime executes real AOT artifacts (L1+L2 -> L3).
//!
//! Requires `make artifacts`. These tests prove the full interchange path:
//! jax/pallas -> HLO text -> PJRT compile -> execute -> numerics match a
//! pure-Rust reference.

use sten::kernels::dense_gemm;
use sten::runtime::{ArtifactRuntime, Value};
use sten::tensor::DenseTensor;
use sten::util::rng::Pcg64;

fn runtime() -> ArtifactRuntime {
    // Tests run from the crate root; artifacts/ lives beside Cargo.toml.
    ArtifactRuntime::open_default().expect("run `make artifacts` before cargo test")
}

#[test]
fn manifest_lists_expected_artifacts() {
    let rt = runtime();
    let names = rt.manifest().names();
    for required in [
        "gemm_dense_8x48x16",
        "gemm_masked_8x48x16",
        "gemm_nmg_8x48x16",
        "encoder_fwd_tiny",
        "attn_block_tiny",
        "ffn_block_tiny",
        "ffn_block_nmg_tiny",
        "embed_tiny",
        "lm_head_tiny",
        "train_step_tiny",
    ] {
        assert!(names.contains(&required), "missing artifact {required}; have {names:?}");
    }
}

#[test]
fn dense_gemm_artifact_matches_rust_reference() {
    let rt = runtime();
    let mut rng = Pcg64::seeded(1);
    let a = DenseTensor::randn(&[8, 48], &mut rng);
    let b = DenseTensor::randn(&[48, 16], &mut rng);
    let got = rt
        .call1("gemm_dense_8x48x16", &[a.clone().into(), b.clone().into()])
        .unwrap();
    let want = dense_gemm::matmul_naive(&a, &b);
    assert!(
        got.allclose(&want, 1e-4, 1e-4),
        "max diff {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn masked_gemm_artifact_applies_mask() {
    let rt = runtime();
    let mut rng = Pcg64::seeded(2);
    let a = DenseTensor::randn(&[8, 48], &mut rng);
    let mask = DenseTensor::from_vec(
        &[8, 48],
        (0..8 * 48).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect(),
    );
    let b = DenseTensor::randn(&[48, 16], &mut rng);
    let got = rt
        .call1(
            "gemm_masked_8x48x16",
            &[a.clone().into(), mask.clone().into(), b.clone().into()],
        )
        .unwrap();
    let want = dense_gemm::matmul_naive(&a.zip(&mask, |x, m| x * m), &b);
    assert!(got.allclose(&want, 1e-4, 1e-4));
}

#[test]
fn nmg_gemm_artifact_matches_rust_nmg_kernel() {
    use sten::formats::nmg::NmgTensor;

    let rt = runtime();
    let spec = rt.spec("gemm_nmg_8x48x16").unwrap().clone();
    let (m, n, g) = (
        spec.meta.get("m").unwrap().usize().unwrap(),
        spec.meta.get("n").unwrap().usize().unwrap(),
        spec.meta.get("g").unwrap().usize().unwrap(),
    );
    let (mm, k) = (
        spec.meta.get("M").unwrap().usize().unwrap(),
        spec.meta.get("K").unwrap().usize().unwrap(),
    );
    let nn = spec.inputs.iter().find(|i| i.name == "b").unwrap().shape[1];

    let mut rng = Pcg64::seeded(3);
    let a = DenseTensor::randn(&[mm, k], &mut rng);
    let sparse = NmgTensor::from_dense(&a, n, m, g);
    let b = DenseTensor::randn(&[k, nn], &mut rng);

    // Feed the Rust-converted val/idx into the Pallas artifact.
    let val_spec = &spec.inputs[spec.input_index("val").unwrap()];
    let idx_spec = &spec.inputs[spec.input_index("idx").unwrap()];
    let val = DenseTensor::from_vec(&val_spec.shape, sparse.val_flat().to_vec());
    let idx = Value::I32(
        idx_spec.shape.clone(),
        sparse.idx_flat().iter().map(|&i| i as i32).collect(),
    );
    let got = rt
        .call1("gemm_nmg_8x48x16", &[val.into(), idx, b.clone().into()])
        .unwrap();

    // Rust n:m:g GEMM must agree with the Pallas kernel bit-for-bit-ish.
    let want = sten::kernels::nmg_gemm::spmm(&sparse, &b);
    assert!(
        got.allclose(&want, 1e-4, 1e-4),
        "pallas vs rust n:m:g mismatch: {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn encoder_blocks_compose_to_full_forward() {
    let rt = runtime();
    let spec = rt.spec("encoder_fwd_tiny").unwrap().clone();
    let mut rng = Pcg64::seeded(4);

    // Build params per manifest order; tokens last.
    let mut inputs = Vec::new();
    for io in &spec.inputs {
        match io.name.as_str() {
            "tokens" => {
                let vocab = spec.meta.get("vocab").unwrap().usize().unwrap() as u32;
                let data: Vec<i32> =
                    (0..io.numel()).map(|_| rng.below(vocab) as i32).collect();
                inputs.push(Value::I32(io.shape.clone(), data));
            }
            name => {
                let t = if name.ends_with("_g") {
                    DenseTensor::ones(&io.shape)
                } else if io.shape.len() == 2 {
                    let mut w = DenseTensor::randn(&io.shape, &mut rng);
                    w.scale((2.0 / io.shape[0] as f32).sqrt());
                    w
                } else {
                    DenseTensor::zeros(&io.shape)
                };
                inputs.push(Value::from(t));
            }
        }
    }
    let full = rt.call1("encoder_fwd_tiny", &inputs).unwrap();

    // Now compose embed -> (attn, ffn)* -> lm_head using the same params.
    let names: Vec<String> = spec.inputs.iter().map(|i| i.name.clone()).collect();
    let by_name = |n: &str| -> Value {
        inputs[names.iter().position(|x| x == n).unwrap()].clone()
    };
    let n_layers = spec.meta.get("n_layers").unwrap().usize().unwrap();

    let mut x = rt
        .call1("embed_tiny", &[by_name("emb"), by_name("pos"), by_name("tokens")])
        .unwrap();
    for l in 0..n_layers {
        let p = |s: &str| by_name(&format!("layer{l}.{s}"));
        x = rt
            .call1(
                "attn_block_tiny",
                &[
                    x.clone().into(),
                    p("ln1_g"), p("ln1_b"),
                    p("wq"), p("bq"), p("wk"), p("bk"),
                    p("wv"), p("bv"), p("wo"), p("bo"),
                ],
            )
            .unwrap();
        x = rt
            .call1(
                "ffn_block_tiny",
                &[
                    x.clone().into(),
                    p("ln2_g"), p("ln2_b"),
                    p("w1"), p("b1"), p("w2"), p("b2"),
                ],
            )
            .unwrap();
    }
    let composed = rt
        .call1(
            "lm_head_tiny",
            &[
                x.into(),
                by_name("lnf_g"), by_name("lnf_b"),
                by_name("out_w"), by_name("out_b"),
            ],
        )
        .unwrap();

    assert!(
        composed.allclose(&full, 1e-3, 1e-3),
        "block composition diverges from full forward: {}",
        composed.max_abs_diff(&full)
    );
}

#[test]
fn train_step_artifact_decreases_loss_and_keeps_masks() {
    let rt = runtime();
    let spec = rt.spec("train_step_tiny").unwrap().clone();
    let mut rng = Pcg64::seeded(5);
    let vocab = spec.meta.get("vocab").unwrap().usize().unwrap() as u32;

    let mut inputs = Vec::new();
    let mut mask_positions = Vec::new();
    for (i, io) in spec.inputs.iter().enumerate() {
        let v = match io.name.as_str() {
            "tokens" | "targets" => Value::I32(
                io.shape.clone(),
                (0..io.numel()).map(|_| rng.below(vocab) as i32).collect(),
            ),
            "lr" => Value::from(DenseTensor::from_vec(&[], vec![0.05])),
            name if name.starts_with("mask.") => {
                mask_positions.push(i);
                // 50% random mask.
                Value::from(DenseTensor::from_vec(
                    &io.shape,
                    (0..io.numel())
                        .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                        .collect(),
                ))
            }
            name if name.ends_with("_g") => Value::from(DenseTensor::ones(&io.shape)),
            _ if io.shape.len() == 2 => {
                let mut w = DenseTensor::randn(&io.shape, &mut rng);
                w.scale(0.05);
                Value::from(w)
            }
            _ => Value::from(DenseTensor::zeros(&io.shape)),
        };
        inputs.push(v);
    }

    // Run 4 steps, feeding updated params back in.
    let n_params = spec.outputs.len() - 1;
    let mut loss0 = None;
    let mut loss = 0.0;
    for _ in 0..4 {
        let out = rt.call("train_step_tiny", &inputs).unwrap();
        loss = out[0].as_f32().unwrap().data()[0];
        if loss0.is_none() {
            loss0 = Some(loss);
        }
        for (j, v) in out.into_iter().skip(1).enumerate() {
            inputs[j] = v; // params come first in the input list, same order
        }
        assert_eq!(n_params + 1, spec.outputs.len());
    }
    assert!(
        loss < loss0.unwrap(),
        "loss did not decrease: {loss} !< {:?}",
        loss0
    );

    // Masked params stay masked.
    for &mi in &mask_positions {
        let mask_name = spec.inputs[mi].name.strip_prefix("mask.").unwrap().to_string();
        let pi = spec.input_index(&mask_name).unwrap();
        let param = inputs[pi].as_f32().unwrap();
        let mask = inputs[mi].as_f32().unwrap();
        let leaked = param
            .data()
            .iter()
            .zip(mask.data())
            .filter(|&(p, m)| *m == 0.0 && *p != 0.0)
            .count();
        assert_eq!(leaked, 0, "param {mask_name} has {leaked} unmasked values");
    }
}

#[test]
fn call_rejects_wrong_shapes_and_counts() {
    let rt = runtime();
    let a = DenseTensor::zeros(&[2, 2]);
    let err = rt.call("gemm_dense_8x48x16", &[a.clone().into()]).unwrap_err();
    assert!(err.to_string().contains("expected 2 inputs"), "{err}");
    let b = DenseTensor::zeros(&[48, 16]);
    let err = rt
        .call("gemm_dense_8x48x16", &[a.into(), b.into()])
        .unwrap_err();
    assert!(err.to_string().contains("expects"), "{err}");
}

#[test]
fn timing_buckets_populated() {
    let rt = runtime();
    let mut rng = Pcg64::seeded(6);
    let a = DenseTensor::randn(&[8, 48], &mut rng);
    let b = DenseTensor::randn(&[48, 16], &mut rng);
    rt.call1("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap();
    let t = rt.timing();
    assert!(t.secs("compile") > 0.0);
    assert!(t.secs("execute") > 0.0);
    assert!(t.secs("transfer") > 0.0);
}
