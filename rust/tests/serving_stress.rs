//! Concurrency stress for `ConcurrentServer`: many submitter threads x a
//! small (backpressuring) queue x several replicas, asserting exactly-once
//! completion and no lost requests under the per-worker completion buffers.
//!
//! Kept as a single `#[test]` so the in-binary phases run sequentially and
//! the global kernel-user accounting can be asserted without races. Sized
//! to stay quick in debug `cargo test`; `ci.sh` also runs this binary under
//! `--release` as a timed tripwire, so a reintroduced global lock on the
//! completion path shows up as a wall-clock regression there.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

use sten::coordinator::{ConcurrentServer, Engine, FfnMode, ServeConfig};
use sten::runtime::ArtifactRuntime;
use sten::util::rng::Pcg64;
use sten::util::threadpool;

fn tiny_engine() -> Engine {
    let rt = ArtifactRuntime::open_default().expect("artifact runtime");
    Engine::new(rt, "tiny", FfnMode::NativeNmg { n: 2, m: 4, g: 4 }, 42).unwrap()
}

#[test]
fn stress_exactly_once_completion_under_contention() {
    let users_before = threadpool::active_kernel_users();

    let engine = tiny_engine();
    let seq = engine.dims.seq;
    let vocab = engine.dims.vocab as u32;
    // Small queue forces submit backpressure; several replicas race on the
    // batch channel and the completion accounting.
    let cfg = ServeConfig {
        replicas: 3,
        queue_cap: 4,
        max_wait: Duration::from_millis(1),
        ..ServeConfig::default()
    };
    let server = Arc::new(ConcurrentServer::start(engine, cfg).unwrap());

    let submitters = 8usize;
    let per_thread = 24usize;
    let total = submitters * per_thread;

    let mut handles = Vec::new();
    for t in 0..submitters {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(1000 + t as u64, t as u64);
            let mut ids = Vec::with_capacity(per_thread);
            for _ in 0..per_thread {
                let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
                ids.push(server.submit(&toks).unwrap());
            }
            ids
        }));
    }

    // Poll snapshots while submitters run: merged per-worker buffers must
    // always be a consistent prefix (no duplicates, never more than total).
    loop {
        let done = handles.iter().all(|h| h.is_finished());
        let snap = server.completed();
        let snap_ids: HashSet<u64> = snap.iter().map(|r| r.id).collect();
        assert_eq!(snap_ids.len(), snap.len(), "duplicate ids in snapshot");
        assert!(snap.len() <= total, "snapshot larger than the request stream");
        if done {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    let mut submitted: Vec<u64> = Vec::new();
    for h in handles {
        submitted.extend(h.join().unwrap());
    }
    assert_eq!(submitted.len(), total);

    server.drain();
    let server = Arc::try_unwrap(server).ok().expect("all submitter handles joined");
    let report = server.finish().unwrap();

    // Exactly-once completion: every submitted id completes exactly once.
    assert_eq!(report.results.len(), total, "lost or duplicated completions");
    let completed_ids: HashSet<u64> = report.results.iter().map(|r| r.id).collect();
    assert_eq!(completed_ids.len(), total, "duplicate completion ids");
    let submitted_ids: HashSet<u64> = submitted.into_iter().collect();
    assert_eq!(completed_ids, submitted_ids, "completed ids != submitted ids");

    // Per-batch rider counts partition the request stream.
    let mut per_batch: HashMap<u64, usize> = HashMap::new();
    for r in &report.results {
        per_batch.insert(r.batch_id, r.batch_size);
    }
    let riders: usize = per_batch.values().sum();
    assert_eq!(riders, total, "batch rider counts must partition the requests");
    assert!(report.batches as usize >= per_batch.len());

    // Backpressure held: the queue never grew past the channel cap plus one
    // in-flight submission per submitter thread plus one forming batch.
    assert!(
        report.queue_high_water <= 4 + submitters + 8,
        "queue high-water {} exceeded cap + submitters + batch slack",
        report.queue_high_water
    );

    // The replicas' kernel-thread shares were returned on shutdown.
    assert_eq!(threadpool::active_kernel_users(), users_before);
}
