//! Sharded-vs-unsharded forward equivalence: tensor-parallel execution
//! ([`Engine::shard`]) must reproduce the single-engine forward — dense
//! modes **bit-identically** (the sharded GEMMs are row slices of
//! transposed products with matching tile structure, see
//! `coordinator::shard`), sparse modes allclose — across shard counts
//! 1/2/4 and ragged head/hidden divisions, for both W2 seam modes.

use std::sync::Arc;

use sten::coordinator::{shard_bounds, Engine, FfnMode, SeamMode};
use sten::runtime::ArtifactRuntime;
use sten::util::rng::Pcg64;

fn engine(tag: &str, mode: FfnMode) -> Engine {
    let rt = ArtifactRuntime::open_default().expect("artifact runtime");
    Engine::new(rt, tag, mode, 42).unwrap()
}

#[test]
fn dense_sharded_forward_is_bit_identical_across_shard_counts() {
    let mut e = engine("tiny", FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(7);
    let tokens = e.random_tokens(&mut rng);
    let want = e.forward(&tokens).unwrap();
    // W = 3 exercises ragged divisions everywhere: tiny has 2 heads (one
    // shard gets none) and none of d_model/d_ff/vocab divide by 3.
    for w in [1, 2, 3, 4] {
        let mut sharded = e.shard(w).unwrap();
        let got = sharded.forward(&tokens);
        assert_eq!(got.shape(), want.shape(), "w={w}");
        assert_eq!(got.data(), want.data(), "w={w}: dense sharding must be bit-identical");
    }
}

#[test]
fn nmg_sharded_forward_matches_unsharded() {
    let mut e = engine("tiny", FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
    let mut rng = Pcg64::seeded(8);
    let tokens = e.random_tokens(&mut rng);
    let want = e.forward(&tokens).unwrap();
    // tiny d_ff = 64 with m = 4 -> 16 slabs; w = 3 leaves a ragged slab
    // split. Sparse formats are asserted allclose (the slab slices are
    // exact, but the unsharded nmg path transposes before the W2 GEMM).
    for w in [1, 2, 3, 4] {
        let mut sharded = e.shard(w).unwrap();
        let got = sharded.forward(&tokens);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "w={w}: nmg sharded diverges: {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn autotuned_sharded_forward_matches_unsharded() {
    use sten::tune::{Autotuner, TunePolicy};
    let mut e = engine("tiny", FfnMode::NativeNmg { n: 2, m: 4, g: 2 });
    let mut tuner = Autotuner::new(TunePolicy::CostModel);
    e.autotune_ffn(&mut tuner).unwrap();
    let mut rng = Pcg64::seeded(9);
    let tokens = e.random_tokens(&mut rng);
    let want = e.forward(&tokens).unwrap();
    for w in [2, 4] {
        let mut sharded = e.shard(w).unwrap();
        let got = sharded.forward(&tokens);
        assert!(
            got.allclose(&want, 1e-5, 1e-5),
            "w={w}: autotuned sharded diverges: {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn allreduce_seam_matches_unsharded_allclose() {
    let mut e = engine("tiny", FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(10);
    let tokens = e.random_tokens(&mut rng);
    let want = e.forward(&tokens).unwrap();
    for w in [2, 3, 4] {
        let mut sharded = e.shard_with_seam(w, SeamMode::Allreduce).unwrap();
        let got = sharded.forward(&tokens);
        // The ring reduction sums hidden-slice partials in a different
        // order than the unsharded GEMM's k-loop: allclose, not bit-equal.
        assert!(
            got.allclose(&want, 1e-4, 1e-4),
            "w={w}: allreduce seam diverges: {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn base_config_sharded_forward_is_bit_identical() {
    // The default bench shape: base has 4 heads, d_model 256, d_ff 1024.
    let mut e = engine("base", FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(11);
    let tokens = e.random_tokens(&mut rng);
    let want = e.forward(&tokens).unwrap();
    let mut sharded = e.shard(2).unwrap();
    let got = sharded.forward(&tokens);
    assert_eq!(got.data(), want.data(), "base w=2 must be bit-identical");
}

#[test]
fn sharded_replicas_share_slices_and_agree() {
    let e = engine("tiny", FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(12);
    let tokens = e.random_tokens(&mut rng);
    let mut a = e.shard(2).unwrap();
    let mut b = a.replicate();
    let la = a.forward(&tokens);
    let lb = b.forward(&tokens);
    assert_eq!(la.data(), lb.data(), "replicas must agree bitwise");

    // Replicas can run concurrently: each has its own collective group.
    let tokens = Arc::new(tokens);
    let t2 = Arc::clone(&tokens);
    let h = std::thread::spawn(move || b.forward(&t2));
    let la2 = a.forward(&tokens);
    let lb2 = h.join().unwrap();
    assert_eq!(la2.data(), lb2.data());
}

#[test]
fn shard_timing_is_populated_per_rank() {
    let e = engine("tiny", FfnMode::NativeDense);
    let mut rng = Pcg64::seeded(13);
    let tokens = e.random_tokens(&mut rng);
    let mut sharded = e.shard(2).unwrap();
    sharded.forward(&tokens);
    let timing = sharded.shard_timing();
    assert_eq!(timing.len(), 2);
    for (rank, t) in timing.iter().enumerate() {
        assert!(t.secs("compute") > 0.0, "rank {rank} recorded no compute time");
        assert!(t.total().as_secs_f64() > 0.0);
    }
    sharded.reset_timing();
    assert_eq!(sharded.shard_timing()[0].total().as_secs_f64(), 0.0);
}

#[test]
fn concurrent_server_serves_a_sharded_model() {
    use std::time::Duration;
    use sten::coordinator::{ConcurrentServer, ModelRegistry, ServeConfig};
    let rt = Arc::new(ArtifactRuntime::open_default().unwrap());
    let mut registry = ModelRegistry::new();
    let e = Engine::with_runtime(Arc::clone(&rt), "tiny", FfnMode::NativeDense, 42).unwrap();
    registry.register_sharded("tp", e, 2, 1, 2).unwrap();
    let cfg = ServeConfig {
        queue_cap: 64,
        max_wait: Duration::from_millis(2),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start_registry(registry, cfg).unwrap();
    let seq = server.dims().seq;
    let mut rng = Pcg64::seeded(14);
    for _ in 0..16 {
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(100) as i32).collect();
        server.submit_to("tp", &toks).unwrap();
    }
    let report = server.finish().unwrap();
    assert_eq!(report.results.len(), 16, "every sharded request completes");
    assert_eq!(report.shard_timing.len(), 1);
    let st = &report.shard_timing[0];
    assert_eq!((st.model.as_str(), st.shards), ("tp", 2));
    for (rank, t) in st.per_rank.iter().enumerate() {
        assert!(t.secs("compute") > 0.0, "rank {rank} recorded no compute time");
    }
}

#[test]
fn shard_bounds_cover_and_align() {
    // Whole-range coverage, monotonicity and alignment for the shapes the
    // sharder uses (heads, d_model, slab- and block-aligned d_ff).
    for &(total, align) in &[(2usize, 1usize), (32, 1), (64, 4), (1024, 4), (2048, 1)] {
        for w in 1..=5 {
            let b = shard_bounds(total, w, align);
            assert_eq!(b.len(), w + 1);
            assert_eq!(b[0], 0);
            assert_eq!(b[w], total);
            for i in 0..w {
                assert!(b[i] <= b[i + 1]);
                if b[i + 1] != total {
                    assert_eq!(b[i + 1] % align, 0, "interior bound off alignment");
                }
            }
        }
    }
}
