//! Repo-invariant lints for the `sten` crate.
//!
//! Usage: `cargo run -p xtask -- lint [src-dir]`
//!
//! Six rules, all enforced over `rust/src` (test modules are exempt where
//! noted). The checker is deliberately line-based and syntactic: it strips
//! comments and string literals, then pattern-matches. That keeps it
//! dependency-free (the build environment is offline) at the cost of some
//! precision; every rule errs on the side of flagging, and the unit tests
//! below pin both the positive and negative cases.
//!
//! 1. `unsafe-safety-comment` — every `unsafe` token in code must have a
//!    `// SAFETY:` (or `// Safety:`) comment on the same line or within the
//!    10 preceding lines.
//! 2. `guard-across-scope` — a named `Mutex`/`RwLock` guard binding
//!    (`let g = x.lock()...`) must not be live across a threadpool scope
//!    call (`parallel_for` / `scope_chunks`): workers calling back into the
//!    lock would deadlock against the parked owner.
//! 3. `spawn-outside-util` — `thread::spawn(` is only allowed under
//!    `src/util/`; everything else must go through the pool abstractions so
//!    the loom lane models every thread in the system.
//! 4. `std-sync-in-ported-file` — files ported to the `util::sync` shim must
//!    not name `std::sync` / `std::thread` directly (outside `#[cfg(test)]`),
//!    otherwise the loom lane silently stops covering them.
//! 5. `arch-outside-simd` — `std::arch` / `core::arch` intrinsics,
//!    `#[target_feature]`, and `is_x86_feature_detected!` are only permitted
//!    under `kernels/simd/`; everything else dispatches through the backend
//!    so the scalar reference path stays the single source of truth.
//! 6. `target-feature-without-guard` — a file containing `#[target_feature]`
//!    fns must also contain a runtime-detection guard (`have_avx2_fma(` or
//!    `is_x86_feature_detected!`), so no vectorized fn is reachable on a CPU
//!    that cannot execute it.

use std::fmt;
use std::path::{Path, PathBuf};

/// Files that have been ported to the `util::sync` shim (rule 4).
const PORTED_FILES: &[&str] = &[
    "util/threadpool.rs",
    "util/channel.rs",
    "coordinator/concurrent.rs",
    "dist/collective.rs",
    "coordinator/shard.rs",
];

/// How many lines above an `unsafe` token a SAFETY comment may sit (rule 1).
const SAFETY_WINDOW: usize = 10;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let root = args
                .get(1)
                .map(PathBuf::from)
                .unwrap_or_else(default_src_root);
            match lint_tree(&root) {
                Ok(violations) if violations.is_empty() => {
                    println!("xtask lint: OK ({})", root.display());
                }
                Ok(violations) => {
                    for v in &violations {
                        eprintln!("{v}");
                    }
                    eprintln!("xtask lint: {} violation(s)", violations.len());
                    std::process::exit(1);
                }
                Err(e) => {
                    eprintln!("xtask lint: i/o error: {e}");
                    std::process::exit(2);
                }
            }
        }
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [src-dir]");
            std::process::exit(2);
        }
    }
}

/// `rust/xtask` → sibling `rust/src`.
fn default_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask manifest dir has a parent")
        .join("src")
}

/// One lint finding: `file:line: [rule] message`.
#[derive(Debug, PartialEq, Eq)]
struct Violation {
    file: String,
    /// 1-based.
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Walk `root` and lint every `.rs` file, in path order (deterministic output).
fn lint_tree(root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(lint_source(&rel, &text));
    }
    Ok(out)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint a single file's text. `rel` is the path relative to the src root,
/// with forward slashes (it selects which rules apply).
fn lint_source(rel: &str, text: &str) -> Vec<Violation> {
    let raw: Vec<&str> = text.lines().collect();
    let code = strip_comments_and_strings(&raw);
    let in_test = mark_test_regions(&code);
    let mut out = Vec::new();
    check_safety_comments(rel, &raw, &code, &mut out);
    check_guard_across_scope(rel, &code, &in_test, &mut out);
    check_spawn_outside_util(rel, &code, &in_test, &mut out);
    check_std_sync_in_ported(rel, &code, &in_test, &mut out);
    check_arch_outside_simd(rel, &code, &mut out);
    check_target_feature_guard(rel, &code, &mut out);
    out.sort_by_key(|v| v.line);
    out
}

/// Per-line view of the source with comments and string/char literals
/// blanked out. Block-comment state carries across lines; string state does
/// not (multi-line string literals are rare enough in this tree to ignore,
/// and ignoring them only risks over-flagging, never under-flagging rules
/// 2–4).
fn strip_comments_and_strings(lines: &[&str]) -> Vec<String> {
    let mut out = Vec::with_capacity(lines.len());
    let mut block_depth = 0usize;
    for line in lines {
        let chars: Vec<char> = line.chars().collect();
        let mut code = String::with_capacity(line.len());
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            if block_depth > 0 {
                if c == '*' && next == Some('/') {
                    block_depth -= 1;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    block_depth += 1;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            match c {
                '/' if next == Some('/') => break, // line or doc comment
                '/' if next == Some('*') => {
                    block_depth += 1;
                    i += 2;
                }
                '"' => {
                    // Skip to the unescaped closing quote (or end of line).
                    code.push(' ');
                    i += 1;
                    while i < chars.len() {
                        if chars[i] == '\\' {
                            i += 2;
                        } else if chars[i] == '"' {
                            i += 1;
                            break;
                        } else {
                            i += 1;
                        }
                    }
                }
                '\'' => {
                    // Char literal ('x', '\n') vs lifetime ('a): a lifetime
                    // never closes with a quote right after one character.
                    let is_char_literal = match next {
                        Some('\\') => true,
                        Some(_) => chars.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    if is_char_literal {
                        code.push(' ');
                        i += 2;
                        while i < chars.len() && chars[i] != '\'' {
                            i += 1;
                        }
                        i += 1; // closing quote
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                _ => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(code);
    }
    out
}

/// Mark lines belonging to a `#[cfg(test)]` item. The attribute's item is
/// skipped as a whole brace scope; since those items are self-balanced, the
/// surrounding depth bookkeeping in other checks stays consistent when the
/// whole region is skipped.
fn mark_test_regions(code: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        if !code[i].trim_start().starts_with("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < code.len() {
            in_test[j] = true;
            for ch in code[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    in_test
}

/// True if `code` contains `word` as a standalone token (not part of a
/// longer identifier such as `unsafe_op_in_unsafe_fn`).
fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let p = start + pos;
        let before_ok = match code[..p].chars().next_back() {
            None => true,
            Some(c) => !c.is_alphanumeric() && c != '_',
        };
        let after_ok = match code[p + word.len()..].chars().next() {
            None => true,
            Some(c) => !c.is_alphanumeric() && c != '_',
        };
        if before_ok && after_ok {
            return true;
        }
        start = p + word.len();
    }
    false
}

/// Rule 1: every `unsafe` token needs a nearby SAFETY comment. Applies to
/// test code too — unsafe is unsafe wherever it lives.
fn check_safety_comments(rel: &str, raw: &[&str], code: &[String], out: &mut Vec<Violation>) {
    for (i, c) in code.iter().enumerate() {
        if !has_word(c, "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(SAFETY_WINDOW);
        let annotated = raw[lo..=i]
            .iter()
            .any(|l| l.contains("SAFETY") || l.contains("Safety"));
        if !annotated {
            out.push(Violation {
                file: rel.to_string(),
                line: i + 1,
                rule: "unsafe-safety-comment",
                msg: format!(
                    "`unsafe` without a `// SAFETY:` comment on the same line \
                     or within the {SAFETY_WINDOW} preceding lines"
                ),
            });
        }
    }
}

/// Rule 2: no named lock guard live across a threadpool scope call.
///
/// Tracks `let g = ...lock()/...read()/...write()` bindings together with
/// their brace depth; a binding dies at `drop(g)` or when its scope closes.
/// Temporaries (`x.lock().unwrap().push(..)`) and tuple patterns
/// (`let (g, t) = cv.wait_timeout(..)`) are not tracked — the former die at
/// the end of the statement, the latter are the condvar idiom where the
/// guard is consumed by the wait loop itself.
fn check_guard_across_scope(
    rel: &str,
    code: &[String],
    in_test: &[bool],
    out: &mut Vec<Violation>,
) {
    let mut depth: i64 = 0;
    // (binding name, brace depth it lives at, 1-based decl line)
    let mut guards: Vec<(String, i64, usize)> = Vec::new();
    for (i, c) in code.iter().enumerate() {
        if in_test[i] {
            continue; // self-balanced region; depth unaffected
        }
        let trimmed = c.trim_start();
        // Definition lines (`pub fn scope_chunks<F>(...)`) name the scope
        // entry points without calling them.
        let is_fn_def = has_word(c, "fn");
        if !guards.is_empty()
            && !is_fn_def
            && (c.contains("parallel_for(") || c.contains("scope_chunks"))
        {
            let (name, _, decl) = &guards[0];
            out.push(Violation {
                file: rel.to_string(),
                line: i + 1,
                rule: "guard-across-scope",
                msg: format!(
                    "lock guard `{name}` (acquired on line {decl}) is live across a \
                     threadpool scope call; drop it first — workers re-entering the \
                     lock deadlock against the parked scope owner"
                ),
            });
        }
        if let Some(pos) = c.find("drop(") {
            let dropped: String = c[pos + "drop(".len()..]
                .chars()
                .take_while(|ch| ch.is_alphanumeric() || *ch == '_')
                .collect();
            guards.retain(|(n, _, _)| *n != dropped);
        }
        if let Some(name) = guard_binding(trimmed) {
            guards.push((name, depth, i + 1));
        }
        depth += c
            .chars()
            .map(|ch| match ch {
                '{' => 1,
                '}' => -1,
                _ => 0,
            })
            .sum::<i64>();
        guards.retain(|(_, d, _)| *d <= depth);
    }
}

/// `let [mut] NAME = <rhs containing .lock()/.read()/.write()>` → `NAME`.
fn guard_binding(trimmed: &str) -> Option<String> {
    let rest = trimmed.strip_prefix("let ")?;
    let eq = rest.find('=')?;
    let (pat, rhs) = rest.split_at(eq);
    if !(rhs.contains(".lock()") || rhs.contains(".read()") || rhs.contains(".write()")) {
        return None;
    }
    let pat = pat.trim();
    let pat = pat.strip_prefix("mut ").unwrap_or(pat).trim_start();
    let name: String = pat
        .chars()
        .take_while(|ch| ch.is_alphanumeric() || *ch == '_')
        .collect();
    if name.is_empty() {
        None // tuple/struct pattern — not a plain guard binding
    } else {
        Some(name)
    }
}

/// Rule 3: `thread::spawn(` only under `src/util/` (tests exempt: they may
/// spawn driver threads to exercise the public API from outside).
fn check_spawn_outside_util(
    rel: &str,
    code: &[String],
    in_test: &[bool],
    out: &mut Vec<Violation>,
) {
    if rel.starts_with("util/") {
        return;
    }
    for (i, c) in code.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        if c.contains("thread::spawn(") {
            out.push(Violation {
                file: rel.to_string(),
                line: i + 1,
                rule: "spawn-outside-util",
                msg: "`thread::spawn` outside `util/`; route threads through \
                      `util::threadpool` / `util::sync::thread` so the loom lane \
                      models them"
                    .to_string(),
            });
        }
    }
}

/// Rule 4: shim-ported files must not reach for `std::sync` / `std::thread`
/// directly (outside tests) — that would bypass the loom instrumentation.
fn check_std_sync_in_ported(
    rel: &str,
    code: &[String],
    in_test: &[bool],
    out: &mut Vec<Violation>,
) {
    if !PORTED_FILES.contains(&rel) {
        return;
    }
    for (i, c) in code.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        for needle in ["std::sync", "std::thread"] {
            if c.contains(needle) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "std-sync-in-ported-file",
                    msg: format!(
                        "direct `{needle}` in a file ported to the `util::sync` shim; \
                         import from `crate::util::sync` instead"
                    ),
                });
            }
        }
    }
}

/// Tokens that mark direct use of CPU intrinsics (rule 5). Applies to test
/// code too: a test exercising raw intrinsics belongs next to them.
const ARCH_TOKENS: &[&str] = &[
    "std::arch",
    "core::arch",
    "#[target_feature",
    "is_x86_feature_detected!",
];

/// Rule 5: CPU intrinsics only under `kernels/simd/`. Everywhere else must
/// call the safe wrappers, which carry the runtime-detection guard and the
/// scalar fallback.
fn check_arch_outside_simd(rel: &str, code: &[String], out: &mut Vec<Violation>) {
    if rel.starts_with("kernels/simd/") {
        return;
    }
    for (i, c) in code.iter().enumerate() {
        for needle in ARCH_TOKENS {
            if c.contains(needle) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "arch-outside-simd",
                    msg: format!(
                        "`{needle}` outside `kernels/simd/`; call the safe \
                         `kernels::simd` wrappers instead so runtime feature \
                         detection and the scalar fallback stay centralized"
                    ),
                });
            }
        }
    }
}

/// Rule 6: a file declaring `#[target_feature]` fns must also contain a
/// runtime-detection guard. The guard being *somewhere in the file* is the
/// syntactic proxy for "every vectorized fn is reached through a detection
/// check" (the module convention: private `#[target_feature]` fns, public
/// wrappers that test `have_avx2_fma()` first).
fn check_target_feature_guard(rel: &str, code: &[String], out: &mut Vec<Violation>) {
    let guarded = code
        .iter()
        .any(|c| c.contains("have_avx2_fma(") || c.contains("is_x86_feature_detected!"));
    if guarded {
        return;
    }
    for (i, c) in code.iter().enumerate() {
        if c.contains("#[target_feature") {
            out.push(Violation {
                file: rel.to_string(),
                line: i + 1,
                rule: "target-feature-without-guard",
                msg: "`#[target_feature]` fn in a file with no runtime-detection \
                      guard (`have_avx2_fma(` / `is_x86_feature_detected!`); \
                      calling it on an unsupported CPU is undefined behavior"
                    .to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(violations: &[Violation]) -> Vec<&'static str> {
        violations.iter().map(|v| v.rule).collect()
    }

    // ---- rule 1: unsafe-safety-comment -------------------------------

    #[test]
    fn unannotated_unsafe_is_flagged() {
        let src = "fn f(p: *mut u8) {\n    unsafe { *p = 1 };\n}\n";
        let v = lint_source("kernels/x.rs", src);
        assert_eq!(rules(&v), ["unsafe-safety-comment"]);
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn safety_comment_within_window_passes() {
        let src = "fn f(p: *mut u8) {\n    // SAFETY: caller guarantees p is valid.\n    \
                   unsafe { *p = 1 };\n}\n";
        assert!(lint_source("kernels/x.rs", src).is_empty());
    }

    #[test]
    fn safety_comment_beyond_window_is_flagged() {
        let mut src = String::from("// SAFETY: too far away.\n");
        for _ in 0..SAFETY_WINDOW {
            src.push_str("fn pad() {}\n");
        }
        src.push_str("fn f(p: *mut u8) { unsafe { *p = 1 }; }\n");
        assert_eq!(rules(&lint_source("kernels/x.rs", &src)), ["unsafe-safety-comment"]);
    }

    #[test]
    fn unsafe_inside_string_or_comment_is_ignored() {
        let src = "fn f() {\n    let _ = \"unsafe\";\n    // unsafe in a comment\n}\n";
        assert!(lint_source("kernels/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_as_identifier_fragment_is_ignored() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\nfn f() {}\n";
        assert!(lint_source("lib.rs", src).is_empty());
    }

    // ---- rule 2: guard-across-scope ----------------------------------

    #[test]
    fn guard_live_across_parallel_for_is_flagged() {
        let src = "fn f(pool: &ThreadPool, m: &Mutex<u32>) {\n\
                   \x20   let g = m.lock().unwrap();\n\
                   \x20   pool.parallel_for(10, 1, |a, b| work(a, b));\n\
                   \x20   drop(g);\n}\n";
        let v = lint_source("ops/x.rs", src);
        assert_eq!(rules(&v), ["guard-across-scope"]);
        assert_eq!(v[0].line, 3);
        assert!(v[0].msg.contains('g'));
    }

    #[test]
    fn guard_dropped_before_scope_passes() {
        let src = "fn f(pool: &ThreadPool, m: &Mutex<u32>) {\n\
                   \x20   let g = m.lock().unwrap();\n\
                   \x20   let n = *g;\n\
                   \x20   drop(g);\n\
                   \x20   pool.parallel_for(n as usize, 1, |a, b| work(a, b));\n}\n";
        assert!(lint_source("ops/x.rs", src).is_empty());
    }

    #[test]
    fn guard_scope_closed_before_scope_call_passes() {
        let src = "fn f(pool: &ThreadPool, m: &Mutex<u32>) {\n\
                   \x20   {\n\
                   \x20       let g = m.lock().unwrap();\n\
                   \x20       touch(&g);\n\
                   \x20   }\n\
                   \x20   pool.scope_chunks(4, 1, |a, b| work(a, b));\n}\n";
        assert!(lint_source("ops/x.rs", src).is_empty());
    }

    #[test]
    fn lock_temporary_passes() {
        let src = "fn f(pool: &ThreadPool, m: &Mutex<Vec<u32>>) {\n\
                   \x20   m.lock().unwrap().push(1);\n\
                   \x20   pool.parallel_for(4, 1, |a, b| work(a, b));\n}\n";
        assert!(lint_source("ops/x.rs", src).is_empty());
    }

    #[test]
    fn scope_fn_definition_line_is_not_a_call_site() {
        let src = "impl ThreadPool {\n\
                   \x20   pub fn scope_chunks<F>(&self, n: usize, grain: usize, f: F) {\n\
                   \x20       let g = self.state.lock().unwrap();\n\
                   \x20       drop(g);\n\
                   \x20   }\n}\n";
        assert!(lint_source("util/threadpool.rs", src).is_empty());
    }

    #[test]
    fn rwlock_read_guard_is_tracked() {
        let src = "fn f(pool: &ThreadPool, m: &RwLock<u32>) {\n\
                   \x20   let snapshot = m.read().unwrap();\n\
                   \x20   pool.scope_chunks(4, 1, |a, b| work(a, b));\n}\n";
        assert_eq!(rules(&lint_source("ops/x.rs", src)), ["guard-across-scope"]);
    }

    // ---- rule 3: spawn-outside-util ----------------------------------

    #[test]
    fn spawn_outside_util_is_flagged() {
        let src = "fn f() {\n    let h = thread::spawn(|| {});\n    h.join().unwrap();\n}\n";
        assert_eq!(rules(&lint_source("coordinator/x.rs", src)), ["spawn-outside-util"]);
    }

    #[test]
    fn spawn_inside_util_passes() {
        let src = "fn f() {\n    let h = thread::spawn(|| {});\n    h.join().unwrap();\n}\n";
        assert!(lint_source("util/threadpool.rs", src).is_empty());
    }

    #[test]
    fn spawn_in_test_module_passes() {
        let src = "fn f() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   #[test]\n\
                   \x20   fn t() {\n\
                   \x20       let h = std::thread::spawn(|| {});\n\
                   \x20       h.join().unwrap();\n\
                   \x20   }\n}\n";
        assert!(lint_source("runtime/executor.rs", src).is_empty());
    }

    // ---- rule 4: std-sync-in-ported-file -----------------------------

    #[test]
    fn std_sync_in_ported_file_is_flagged() {
        let src = "use std::sync::Mutex;\nfn f() {}\n";
        let v = lint_source("util/channel.rs", src);
        assert_eq!(rules(&v), ["std-sync-in-ported-file"]);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn std_thread_in_ported_file_is_flagged() {
        let src = "fn f() { std::thread::yield_now(); }\n";
        assert_eq!(
            rules(&lint_source("util/threadpool.rs", src)),
            ["std-sync-in-ported-file"]
        );
    }

    #[test]
    fn std_sync_in_unported_file_passes() {
        let src = "use std::sync::Mutex;\nfn f() {}\n";
        assert!(lint_source("runtime/executor.rs", src).is_empty());
    }

    #[test]
    fn std_sync_in_ported_file_test_module_passes() {
        let src = "use crate::util::sync::Mutex;\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   use std::sync::mpsc;\n\
                   \x20   #[test]\n\
                   \x20   fn t() { let (_tx, _rx) = mpsc::channel::<u32>(); }\n}\n";
        assert!(lint_source("util/channel.rs", src).is_empty());
    }

    // ---- rule 5: arch-outside-simd -----------------------------------

    #[test]
    fn std_arch_outside_simd_is_flagged() {
        let src = "use std::arch::x86_64::*;\nfn f() {}\n";
        let v = lint_source("kernels/dense_gemm.rs", src);
        assert_eq!(rules(&v), ["arch-outside-simd"]);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn target_feature_outside_simd_is_flagged() {
        let src = "fn guard() -> bool { have_avx2_fma() }\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn k() {}\n";
        // Flags the attribute placement (rule 5); rule 1 additionally flags
        // the bare `unsafe`, and the in-file guard satisfies rule 6.
        let v = lint_source("tensor/mod.rs", src);
        assert!(rules(&v).contains(&"arch-outside-simd"), "got {v:?}");
        assert!(!rules(&v).contains(&"target-feature-without-guard"));
    }

    #[test]
    fn arch_under_simd_passes() {
        let src = "use std::arch::x86_64::*;\n\
                   fn have_avx2_fma() -> bool { is_x86_feature_detected!(\"avx2\") }\n";
        assert!(lint_source("kernels/simd/dense.rs", src).is_empty());
    }

    #[test]
    fn arch_token_in_comment_or_string_is_ignored() {
        let src = "// std::arch is documented here\nfn f() { let _ = \"core::arch\"; }\n";
        assert!(lint_source("runtime/executor.rs", src).is_empty());
    }

    // ---- rule 6: target-feature-without-guard ------------------------

    #[test]
    fn target_feature_without_detection_guard_is_flagged() {
        let src = "// SAFETY: caller checked avx2.\n\
                   #[target_feature(enable = \"avx2,fma\")]\n\
                   unsafe fn k(p: *const f32) {}\n";
        let v = lint_source("kernels/simd/rogue.rs", src);
        assert_eq!(rules(&v), ["target-feature-without-guard"]);
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn target_feature_with_detection_guard_passes() {
        let src = "pub fn entry() -> bool {\n\
                   \x20   if !is_x86_feature_detected!(\"avx2\") { return false; }\n\
                   \x20   // SAFETY: avx2 verified above.\n\
                   \x20   unsafe { k() };\n\
                   \x20   true\n\
                   }\n\
                   // SAFETY: only called after the detection check in entry().\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn k() {}\n";
        assert!(lint_source("kernels/simd/ok.rs", src).is_empty());
    }

    // ---- the tree itself ---------------------------------------------

    #[test]
    fn src_tree_is_clean() {
        let root = default_src_root();
        let violations = lint_tree(&root).expect("lint walk");
        assert!(
            violations.is_empty(),
            "expected a clean tree, got:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
